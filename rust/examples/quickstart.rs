//! Quickstart: simulate one benchmark under demand paging, the tree
//! prefetcher, and the DL prefetcher (stride fallback — no artifacts
//! needed), and print the paper's core metrics side by side.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use uvm_prefetch::eval::runner::{run_benchmark, RunOptions};

fn main() -> anyhow::Result<()> {
    let opts = RunOptions {
        scale: 0.25,
        max_instructions: 0, // run the workload to completion
        ..Default::default()
    };
    println!("ATAX (y = AᵀAx) under three prefetch policies\n");
    println!(
        "{:<10} {:>10} {:>8} {:>8} {:>8} {:>8} {:>8} {:>12}",
        "policy", "cycles", "ipc", "hit", "faults", "acc", "unity", "pcie-bytes"
    );
    for policy in ["none", "tree", "dl"] {
        let m = run_benchmark("atax", policy, &opts)?;
        println!(
            "{:<10} {:>10} {:>8.4} {:>8.4} {:>8} {:>8.4} {:>8.4} {:>12}",
            policy,
            m.cycles,
            m.ipc(),
            m.page_hit_rate(),
            m.far_faults,
            m.accuracy(),
            m.unity(),
            m.pcie_bytes(),
        );
    }
    println!("\n(dl used the pure-Rust fallback backend; pass artifacts via");
    println!(" `repro simulate --prefetcher dl --artifacts artifacts` for the real model.)");
    Ok(())
}
