//! Prediction-overhead sensitivity (paper §7.3 / Figure 10): sweep
//! the prediction latency over 1/2/5/10 µs and report normalized IPC
//! against the UVMSmart baseline for one benchmark.
//!
//! Paper averages across the suite: 1.10×, 1.06×, 1.00×, 0.90× —
//! "our predictor, as well as other learning-based methods, are
//! sensitive to the prediction overhead."
//!
//! ```sh
//! cargo run --release --example latency_sweep [benchmark]
//! ```

use uvm_prefetch::eval::runner::{run_benchmark, run_benchmark_with, RunOptions};

fn main() -> anyhow::Result<()> {
    let benchmark = std::env::args().nth(1).unwrap_or_else(|| "pathfinder".to_string());
    let opts = RunOptions {
        scale: 4.0,
        max_instructions: 2_000_000,
        artifacts: if std::path::Path::new("artifacts/manifest.json").exists() {
            "artifacts".into()
        } else {
            String::new() // stride fallback
        },
        ..Default::default()
    };
    let u = run_benchmark(&benchmark, "uvmsmart", &opts)?;
    println!("{benchmark}: UVMSmart IPC = {:.4}\n", u.ipc());
    println!("{:>12} {:>10} {:>16}", "latency(us)", "dl IPC", "normalized(R/U)");
    for us in [1.0f64, 2.0, 5.0, 10.0] {
        let r = run_benchmark_with(
            &benchmark,
            "dl",
            &opts,
            |mut e| {
                e.runtime.prediction_latency_cycles = e.sim.us_to_cycles(us);
                e
            },
            None,
        )?;
        println!("{:>12} {:>10.4} {:>16.3}", us, r.ipc(), r.ipc() / u.ipc());
    }
    Ok(())
}
