//! End-to-end driver — the full three-layer stack on a real workload.
//!
//! Proves all layers compose (DESIGN.md §3): the Rust simulator
//! generates far-faults for a real benchmark; the coordinator clusters
//! them, batches windows, executes the **AOT-compiled JAX/Pallas
//! model through PJRT** (Layer 2/1 artifacts from `make artifacts`),
//! and feeds predicted pages back as prefetches — then reports the
//! paper's headline metrics (IPC, page hit rate, PCIe traffic) against
//! the UVMSmart baseline.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_prefetch [benchmark]
//! ```

use uvm_prefetch::eval::runner::{run_benchmark, RunOptions};

fn main() -> anyhow::Result<()> {
    let benchmark =
        std::env::args().nth(1).unwrap_or_else(|| "pathfinder".to_string());
    let artifacts = std::env::var("ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&artifacts).join("manifest.json").exists() {
        anyhow::bail!("artifacts/ missing — run `make artifacts` first");
    }

    // The paper's operating regime: a fixed instruction window over a
    // working set several times larger (DESIGN.md §5c).
    let opts = RunOptions {
        scale: 4.0,
        max_instructions: 2_000_000,
        artifacts,
        ..Default::default()
    };

    eprintln!("=== e2e: {benchmark} under UVMSmart (baseline U) ===");
    let u = run_benchmark(&benchmark, "uvmsmart", &opts)?;
    eprintln!("{}", u.summary());

    eprintln!("\n=== e2e: {benchmark} under the DL prefetcher (R, PJRT) ===");
    let r = run_benchmark(&benchmark, "dl", &opts)?;
    eprintln!("{}", r.summary());

    println!("\n================= paper-style report =================");
    println!("benchmark           : {benchmark}");
    println!("simulated inst      : {} (U) / {} (R)", u.instructions, r.instructions);
    println!(
        "IPC                 : {:.4} → {:.4}  ({:+.2}%)",
        u.ipc(),
        r.ipc(),
        (r.ipc() / u.ipc() - 1.0) * 100.0
    );
    println!("page hit rate       : {:.4} → {:.4}", u.page_hit_rate(), r.page_hit_rate());
    println!(
        "PCIe traffic        : {} → {} bytes ({:+.2}%)",
        u.pcie_bytes(),
        r.pcie_bytes(),
        (r.pcie_bytes() as f64 / u.pcie_bytes() as f64 - 1.0) * 100.0
    );
    println!("unity (U vs R)      : {:.3} vs {:.3}  (ideal 1.0)", u.unity(), r.unity());
    println!(
        "model predictions   : {} in {} batches ({} bypassed, {} OOV)",
        r.predictions, r.prediction_batches, r.bypass_predictions, r.oov_predictions
    );
    println!("======================================================");
    println!("paper §7.4 reference: IPC +10.89% geomean, hit 76.10%→89.02%,");
    println!("PCIe −11.05%, unity 0.85→0.90 across the 11-benchmark suite.");
    Ok(())
}
