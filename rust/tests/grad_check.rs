//! Central-difference gradient checks (ISSUE 5 satellite): every
//! backward pass in `predictor/nn.rs` — the pre-existing
//! `linear_backward` / fused softmax-CE path *and* the new
//! layer-norm / GELU / attention backwards — is pinned numerically,
//! plus the full Transformer `loss_and_grad` (which composes all of
//! them with residuals and the embedding scatter).
//!
//! All checks are seeded-deterministic: the comparisons run on fixed
//! inputs, so a pass/fail is a property of the code, not the run.

use uvm_prefetch::predictor::nn;
use uvm_prefetch::predictor::transformer::{TransformerBackend, TransformerConfig};
use uvm_prefetch::predictor::{FeatTok, LabelledWindow, Window};
use uvm_prefetch::util::XorShift64;

const EPS: f32 = 5e-3;

/// Relative tolerance with an absolute floor: f32 central differences
/// carry ~2e-5 rounding noise at eps = 5e-3, far below 3% of any
/// gradient that matters; near-zero gradients fall under the floor
/// (the step is kept small because layer-norm curvature grows like
/// 1/σ³ on the low-variance embedded rows).
fn assert_close(analytic: f32, fd: f32, ctx: &str) {
    let tol = 3e-2 * analytic.abs().max(fd.abs()).max(0.05);
    assert!(
        (analytic - fd).abs() <= tol,
        "{ctx}: analytic {analytic} vs central-difference {fd} (tol {tol})"
    );
}

fn randv(rng: &mut XorShift64, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| (rng.unit() as f32 * 2.0 - 1.0) * scale).collect()
}

/// The pre-existing path: linear layer into fused softmax +
/// cross-entropy. Checks dW, db and dx.
#[test]
fn fd_linear_softmax_ce() {
    let (ins, outs) = (5usize, 4usize);
    let mut rng = XorShift64::new(11);
    let w = randv(&mut rng, outs * ins, 0.8);
    let b = randv(&mut rng, outs, 0.5);
    let x = randv(&mut rng, ins, 1.0);
    let label = 2usize;
    let loss = |w: &[f32], b: &[f32], x: &[f32]| -> f32 {
        let mut z = vec![0.0f32; outs];
        nn::linear_forward(w, b, x, &mut z);
        nn::softmax(&mut z);
        -z[label].max(1e-12).ln()
    };
    let mut z = vec![0.0f32; outs];
    nn::linear_forward(&w, &b, &x, &mut z);
    nn::softmax(&mut z);
    let _ = nn::cross_entropy_backward(&mut z, label); // z := dlogits
    let mut dw = vec![0.0f32; outs * ins];
    let mut db = vec![0.0f32; outs];
    let mut dx = vec![0.0f32; ins];
    nn::linear_backward(&w, &x, &z, &mut dw, &mut db, Some(&mut dx));
    for i in 0..w.len() {
        let (mut wp, mut wm) = (w.clone(), w.clone());
        wp[i] += EPS;
        wm[i] -= EPS;
        let fd = (loss(&wp, &b, &x) - loss(&wm, &b, &x)) / (2.0 * EPS);
        assert_close(dw[i], fd, &format!("dW[{i}]"));
    }
    for i in 0..b.len() {
        let (mut bp, mut bm) = (b.clone(), b.clone());
        bp[i] += EPS;
        bm[i] -= EPS;
        let fd = (loss(&w, &bp, &x) - loss(&w, &bm, &x)) / (2.0 * EPS);
        assert_close(db[i], fd, &format!("db[{i}]"));
    }
    for i in 0..x.len() {
        let (mut xp, mut xm) = (x.clone(), x.clone());
        xp[i] += EPS;
        xm[i] -= EPS;
        let fd = (loss(&w, &b, &xp) - loss(&w, &b, &xm)) / (2.0 * EPS);
        assert_close(dx[i], fd, &format!("dx[{i}]"));
    }
}

/// Layer norm under the scalar loss Σ cᵢ·outᵢ: checks dγ, dβ and dx.
#[test]
fn fd_layer_norm() {
    let n = 6usize;
    let mut rng = XorShift64::new(22);
    let x = randv(&mut rng, n, 1.5);
    let gamma = randv(&mut rng, n, 1.0);
    let beta = randv(&mut rng, n, 0.5);
    let c = randv(&mut rng, n, 1.0);
    let loss = |x: &[f32], gamma: &[f32], beta: &[f32]| -> f32 {
        let mut xhat = vec![0.0f32; n];
        let mut out = vec![0.0f32; n];
        nn::layer_norm_forward(x, gamma, beta, &mut xhat, &mut out);
        out.iter().zip(&c).map(|(o, cc)| o * cc).sum()
    };
    let mut xhat = vec![0.0f32; n];
    let mut out = vec![0.0f32; n];
    let rstd = nn::layer_norm_forward(&x, &gamma, &beta, &mut xhat, &mut out);
    let mut dg = vec![0.0f32; n];
    let mut dbeta = vec![0.0f32; n];
    let mut dx = vec![0.0f32; n];
    nn::layer_norm_backward(&c, &gamma, &xhat, rstd, &mut dg, &mut dbeta, &mut dx);
    for i in 0..n {
        let (mut xp, mut xm) = (x.clone(), x.clone());
        xp[i] += EPS;
        xm[i] -= EPS;
        let fd = (loss(&xp, &gamma, &beta) - loss(&xm, &gamma, &beta)) / (2.0 * EPS);
        assert_close(dx[i], fd, &format!("LN dx[{i}]"));

        let (mut gp, mut gm) = (gamma.clone(), gamma.clone());
        gp[i] += EPS;
        gm[i] -= EPS;
        let fd = (loss(&x, &gp, &beta) - loss(&x, &gm, &beta)) / (2.0 * EPS);
        assert_close(dg[i], fd, &format!("LN dγ[{i}]"));

        let (mut bp, mut bm) = (beta.clone(), beta.clone());
        bp[i] += EPS;
        bm[i] -= EPS;
        let fd = (loss(&x, &gamma, &bp) - loss(&x, &gamma, &bm)) / (2.0 * EPS);
        assert_close(dbeta[i], fd, &format!("LN dβ[{i}]"));
    }
}

/// GELU under the scalar loss Σ cᵢ·gelu(xᵢ): checks dx.
#[test]
fn fd_gelu() {
    let n = 9usize;
    let mut rng = XorShift64::new(33);
    let x = randv(&mut rng, n, 2.5);
    let c = randv(&mut rng, n, 1.0);
    let loss = |x: &[f32]| -> f32 {
        let mut out = vec![0.0f32; n];
        nn::gelu_forward(x, &mut out);
        out.iter().zip(&c).map(|(o, cc)| o * cc).sum()
    };
    let mut dx = vec![0.0f32; n];
    nn::gelu_backward(&x, &c, &mut dx);
    for i in 0..n {
        let (mut xp, mut xm) = (x.clone(), x.clone());
        xp[i] += EPS;
        xm[i] -= EPS;
        let fd = (loss(&xp) - loss(&xm)) / (2.0 * EPS);
        assert_close(dx[i], fd, &format!("GELU dx[{i}]"));
    }
}

/// Multi-head attention under the scalar loss Σ c·ctx: checks dq, dk
/// and dv through the softmaxed score path.
#[test]
fn fd_attention() {
    let (seq, heads, dh) = (3usize, 2usize, 2usize);
    let d = heads * dh;
    let mut rng = XorShift64::new(44);
    let q = randv(&mut rng, seq * d, 1.0);
    let k = randv(&mut rng, seq * d, 1.0);
    let v = randv(&mut rng, seq * d, 1.0);
    let c = randv(&mut rng, seq * d, 1.0);
    let loss = |q: &[f32], k: &[f32], v: &[f32]| -> f32 {
        let mut attn = vec![0.0f32; heads * seq * seq];
        let mut ctx = vec![0.0f32; seq * d];
        nn::attention_forward(q, k, v, seq, heads, dh, &mut attn, &mut ctx);
        ctx.iter().zip(&c).map(|(o, cc)| o * cc).sum()
    };
    let mut attn = vec![0.0f32; heads * seq * seq];
    let mut ctx = vec![0.0f32; seq * d];
    nn::attention_forward(&q, &k, &v, seq, heads, dh, &mut attn, &mut ctx);
    let mut dq = vec![0.0f32; seq * d];
    let mut dk = vec![0.0f32; seq * d];
    let mut dv = vec![0.0f32; seq * d];
    let mut scratch = vec![0.0f32; seq];
    nn::attention_backward(
        &q, &k, &v, &attn, &c, seq, heads, dh, &mut dq, &mut dk, &mut dv, &mut scratch,
    );
    for i in 0..seq * d {
        let (mut qp, mut qm) = (q.clone(), q.clone());
        qp[i] += EPS;
        qm[i] -= EPS;
        let fd = (loss(&qp, &k, &v) - loss(&qm, &k, &v)) / (2.0 * EPS);
        assert_close(dq[i], fd, &format!("attn dq[{i}]"));

        let (mut kp, mut km) = (k.clone(), k.clone());
        kp[i] += EPS;
        km[i] -= EPS;
        let fd = (loss(&q, &kp, &v) - loss(&q, &km, &v)) / (2.0 * EPS);
        assert_close(dk[i], fd, &format!("attn dk[{i}]"));

        let (mut vp, mut vm) = (v.clone(), v.clone());
        vp[i] += EPS;
        vm[i] -= EPS;
        let fd = (loss(&q, &k, &vp) - loss(&q, &k, &vm)) / (2.0 * EPS);
        assert_close(dv[i], fd, &format!("attn dv[{i}]"));
    }
}

/// The whole Transformer: `loss_and_grad`'s analytic gradient for
/// EVERY parameter — embeddings, positional table, LN affines, QKV/out
/// projections, FFN and the class head, composed through residuals —
/// must match central differences on the mean-CE loss.
#[test]
fn fd_full_transformer_loss_and_grad() {
    let cfg = TransformerConfig {
        d_model: 4,
        n_heads: 2,
        n_layers: 1,
        d_ff: 8,
        lr: 0.01,
        ..Default::default()
    };
    let mut m = TransformerBackend::with_shape(3, 3, 2, 2, &cfg);
    let mk = |ds: &[i32]| Window {
        tokens: ds.iter().map(|&d| FeatTok { pc_id: 0, page_id: 1, delta_id: d }).collect(),
    };
    let batch = vec![
        LabelledWindow { window: mk(&[0, 1, 2]), label: 1 },
        LabelledWindow { window: mk(&[2, 2, 0]), label: 0 },
    ];
    let (loss, grads) = m.loss_and_grad(&batch);
    assert!(loss.is_finite() && loss > 0.0);
    let n = m.n_params();
    assert_eq!(grads.len(), n);
    let mut nonzero = 0usize;
    for i in 0..n {
        let orig = m.params()[i];
        m.params_mut()[i] = orig + EPS;
        let (lp, _) = m.loss_and_grad(&batch);
        m.params_mut()[i] = orig - EPS;
        let (lm, _) = m.loss_and_grad(&batch);
        m.params_mut()[i] = orig;
        let fd = (lp - lm) / (2.0 * EPS);
        assert_close(grads[i], fd, &format!("transformer param[{i}]"));
        if grads[i].abs() > 1e-4 {
            nonzero += 1;
        }
    }
    // The check must not pass vacuously: most parameters carry signal.
    assert!(nonzero > n / 2, "only {nonzero}/{n} params had non-trivial gradients");
}
