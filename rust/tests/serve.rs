//! Serving-coordinator lifecycle tests: backpressure under tiny
//! channel bounds, shard-count invariance of per-tenant command
//! content, and the `repro serve` load generator end to end on the
//! stride backend.

use std::collections::BTreeMap;
use uvm_prefetch::config::{BypassMode, RuntimeConfig};
use uvm_prefetch::coordinator::{
    CoordinatorService, FaultEvent, PrefetchCommand, SpawnOptions,
};
use uvm_prefetch::eval::runner::RunOptions;
use uvm_prefetch::eval::serve::{bench_serve_json, run, ServeOptions};
use uvm_prefetch::predictor::{ConstantBackend, DeltaVocab};
use uvm_prefetch::types::{AccessOrigin, TenantId};
use uvm_prefetch::util::{Json, XorShift64};

fn event(tenant: TenantId, warp: u16, page: u64, at: u64, miss: bool) -> FaultEvent {
    FaultEvent {
        at,
        pc: 0x44,
        page,
        origin: AccessOrigin { sm: warp % 4, warp, cta: 0, tpc: 0, kernel_id: 0 },
        miss,
        tenant,
    }
}

/// Deterministic multi-tenant event mix: `tenants` streams, each with
/// its own stride pattern (warps 0–2, converging positive deltas →
/// streaming Discards on block advance) plus one ping-pong cluster
/// (warp 3, same page every time, delta 0 → a one-shot ReadMostly
/// Advise), interleaved round-robin.
fn tenant_mix(tenants: u32, per_tenant: u64) -> Vec<FaultEvent> {
    let mut rng = XorShift64::new(0xfeed);
    let mut out = Vec::new();
    for i in 0..per_tenant {
        for t in 0..tenants {
            let warp = (i % 3) as u16;
            let page = 10_000 * t as u64 + (t as u64 + 1) * i;
            out.push(event(t, warp, page, i, rng.unit() < 0.7));
            out.push(event(t, 3, 10_000 * t as u64 + 5_000, i, true));
        }
    }
    out
}

fn spawn_constant(
    shards: usize,
    tenants: usize,
    fault_queue: usize,
    command_queue: usize,
) -> uvm_prefetch::coordinator::CoordinatorHandle {
    let vocab = DeltaVocab::synthetic(vec![1, 2, 4], 4);
    let rcfg = RuntimeConfig {
        history_len: 4,
        batch_size: 4,
        bypass: BypassMode::Never,
        ..Default::default()
    };
    let n_classes = vocab.n_classes();
    let backend = Box::new(ConstantBackend { class: 0, n_classes });
    let sopts = SpawnOptions {
        shards,
        max_tenants: tenants,
        fault_queue,
        command_queue,
        ..Default::default()
    };
    CoordinatorService::spawn(vocab, backend, &rcfg, &sopts)
}

/// Bounded channels fill, the producer blocks — and shutdown's drain
/// loop still unblocks everything: no deadlock, no lost commands.
#[test]
fn backpressure_blocks_producer_without_deadlock() {
    let handle = spawn_constant(2, 1, 2, 2);
    let sender = handle.sender();
    let n_events = 400u64;
    let producer = std::thread::spawn(move || {
        let mut sent = 0u64;
        for i in 0..n_events {
            if sender.send(event(0, (i % 3) as u16, 50 + i, i, true)).is_err() {
                break;
            }
            sent += 1;
        }
        sent
    });
    // Give the producer time to slam into the 2-deep channels: nothing
    // is draining commands yet, so it must be blocked well short of
    // the full stream (2 cmd + 2×2 fault slots + in-flight ≪ 400).
    std::thread::sleep(std::time::Duration::from_millis(100));
    assert!(!producer.is_finished(), "producer should be blocked on backpressure");

    // Shutdown drains the command channel continuously, so the blocked
    // producer finishes its stream and everything joins.
    let report = handle.shutdown();
    let sent = producer.join().expect("producer must not panic");
    assert_eq!(sent, n_events, "producer completed after the drain started");
    let migrates = report
        .commands
        .iter()
        .filter(|c| matches!(c, PrefetchCommand::Migrate { .. }))
        .count() as u64;
    assert_eq!(migrates, n_events, "every miss produced exactly one Migrate");
    assert_eq!(report.dropped_commands, 0, "backpressure must block, not drop");
}

/// Per-tenant command multisets are identical whatever the shard
/// count: a cluster lives wholly on one shard and the backend answers
/// windows statelessly, so sharding only changes interleaving.
#[test]
fn shard_count_does_not_change_per_tenant_commands() {
    let events = tenant_mix(3, 120);
    let mut per_shards: Vec<BTreeMap<TenantId, Vec<PrefetchCommand>>> = Vec::new();
    for shards in [1usize, 4] {
        let handle = spawn_constant(shards, 3, 64, 1 << 16);
        for ev in &events {
            handle.send(*ev).unwrap();
        }
        let report = handle.shutdown();
        assert_eq!(report.dropped_commands, 0);
        let mut by_tenant: BTreeMap<TenantId, Vec<PrefetchCommand>> = BTreeMap::new();
        for c in report.commands {
            by_tenant.entry(c.tenant()).or_default().push(c);
        }
        for cmds in by_tenant.values_mut() {
            cmds.sort(); // multiset comparison: cross-cluster order may vary
        }
        per_shards.push(by_tenant);
    }
    let (one, four) = (&per_shards[0], &per_shards[1]);
    assert_eq!(
        one.keys().collect::<Vec<_>>(),
        four.keys().collect::<Vec<_>>(),
        "same tenant set"
    );
    for (tenant, cmds) in one {
        assert_eq!(
            cmds,
            &four[tenant],
            "tenant {tenant}: command multiset changed with shard count"
        );
    }
    // The invariance claim must cover the whole vocabulary: the mix is
    // built to emit every command variant, not just Migrate/Predicted.
    let all: Vec<&PrefetchCommand> = one.values().flatten().collect();
    assert!(
        all.iter().any(|c| matches!(c, PrefetchCommand::Advise { .. })),
        "mix produced no Advise commands — the test lost its coverage"
    );
    assert!(
        all.iter().any(|c| matches!(c, PrefetchCommand::Discard { .. })),
        "mix produced no Discard commands — the test lost its coverage"
    );
}

/// The load generator end to end on the stride backend: two tenant
/// streams through two shards, telemetry fully populated and the
/// BENCH JSON well-formed.
#[test]
fn serve_load_generator_smoke_stride() {
    let opts = ServeOptions {
        benchmarks: vec!["addvectors".to_string()],
        streams: 2,
        shards: 2,
        max_faults: 200,
        bypass: BypassMode::Never,
        metrics_out: None,
        run: RunOptions { scale: 0.05, max_instructions: 100_000, ..Default::default() },
    };
    let r = run(&opts).expect("serve run");
    assert_eq!(r.backend, "stride");
    assert_eq!((r.streams, r.shards), (2, 2));
    assert!(r.misses > 0, "streams produced faults");
    assert!(r.commands > 0, "commands flowed");
    assert_eq!(r.dropped_commands, 0);
    assert_eq!(r.tenants.len(), 2);
    for t in &r.tenants {
        assert!(t.commands > 0, "tenant {} starved", t.tenant);
        assert_eq!(t.commands, t.migrates + t.predicted + t.advises + t.discards);
        assert!(t.latency_us.n == t.commands, "one latency sample per command");
    }
    let total: u64 = r.tenants.iter().map(|t| t.commands).sum();
    assert_eq!(total, r.commands as u64, "tenant command counts partition the total");
    assert!(r.faults_per_ms > 0.0 && r.wall_ms > 0.0);

    let j = bench_serve_json(&r);
    assert_eq!(j.get("schema").and_then(Json::as_str), Some("bench_serve/v1"));
    let text = j.to_string();
    let back = Json::parse(&text).expect("BENCH_serve.json roundtrips");
    assert_eq!(
        back.get("tenants").and_then(Json::as_arr).map(|a| a.len()),
        Some(2)
    );
}

/// Same seed ⇒ same per-tenant serve outcome (command counts), shard
/// count notwithstanding — the `--shards` axis is a pure throughput
/// knob.
#[test]
fn serve_per_tenant_counts_shard_invariant() {
    let base = ServeOptions {
        benchmarks: vec!["addvectors".to_string()],
        streams: 2,
        shards: 1,
        max_faults: 150,
        bypass: BypassMode::Never,
        metrics_out: None,
        run: RunOptions { scale: 0.05, max_instructions: 100_000, ..Default::default() },
    };
    let one = run(&base).expect("1-shard run");
    let four = run(&ServeOptions { shards: 4, ..base.clone() }).expect("4-shard run");
    for (a, b) in one.tenants.iter().zip(&four.tenants) {
        assert_eq!(a.tenant, b.tenant);
        assert_eq!(a.misses, b.misses, "tenant {} replay diverged", a.tenant);
        assert_eq!(a.commands, b.commands, "tenant {} commands diverged", a.tenant);
        assert_eq!(a.migrates, b.migrates);
        assert_eq!(a.predicted, b.predicted);
        assert_eq!(a.advises, b.advises);
        assert_eq!(a.discards, b.discards);
    }
}
