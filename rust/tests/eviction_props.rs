//! Property tests for every eviction policy over real workload page
//! streams — dense AND irregular families.
//!
//! Rather than pinning one recorded trace per policy (the unit tests
//! in `sim/eviction.rs` do that), these tests drive [`DeviceMemory`]
//! with page streams harvested from the builtin workload generators
//! and check the invariants that must hold for *any* policy:
//!
//! 1. **Victim always resident** — `pick_victim` never returns an
//!    in-flight or pinned page (asserted inside an instrumented
//!    policy wrapper, so the check sees exactly what the memory saw).
//! 2. **Resident ≤ capacity** — occupancy never exceeds the frame
//!    budget when at least one page is evictable.
//! 3. **Hook call balance** — `on_admit` calls minus `on_remove`
//!    calls equals live occupancy at every checkpoint: the policy's
//!    index can never leak or double-free an entry.
//! 4. **Double-run byte-identity** — the full eviction sequence is
//!    identical across two runs with the same inputs (the sweep's
//!    determinism contract, including the online-trained learned
//!    policy).
//! 5. **Discard never resurrects** — once a page is eagerly
//!    discarded (or a lazy mark is reclaimed) it stays gone until a
//!    fresh admit; no hook sequence brings a freed frame back.

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use uvm_prefetch::config::SimConfig;
use uvm_prefetch::sim::device_memory::{DeviceMemory, Frame, FrameIdx, PageInfo};
use uvm_prefetch::sim::eviction::{self, EvictionPolicy, ALL_EVICTION_POLICIES};
use uvm_prefetch::types::{page_of, Cycle, PageNum};
use uvm_prefetch::workloads::WorkloadRegistry;

/// Dense (strided/stencil) and irregular (data-dependent) stream
/// sources — the two families whose access shapes stress a victim
/// index differently.
const DENSE: &[&str] = &["addvectors", "atax"];
const IRREGULAR: &[&str] = &["bfs", "spmv", "hash_join"];

/// Accesses per drive — enough to wrap the capped device many times
/// over without making the suite slow.
const STREAM_CAP: usize = 3_000;

/// Harvest a benchmark's page stream: build the generator small, then
/// interleave the per-warp op streams round-robin — the order the
/// GMMU would observe them in.
fn harvest(benchmark: &str) -> Vec<PageNum> {
    let wl = WorkloadRegistry::builtin()
        .build(benchmark, &SimConfig::default(), 42, 0.05)
        .expect("build workload");
    let mut out = Vec::with_capacity(STREAM_CAP);
    let mut idx = 0usize;
    loop {
        let mut any = false;
        for t in &wl.tasks {
            if let Some(op) = t.ops.get(idx) {
                out.push(page_of(op.access.vaddr));
                any = true;
                if out.len() >= STREAM_CAP {
                    return out;
                }
            }
        }
        if !any {
            return out;
        }
        idx += 1;
    }
}

/// A frame budget small enough that the stream wraps it repeatedly.
fn pressure_capacity(stream: &[PageNum]) -> u64 {
    let distinct = stream.iter().collect::<BTreeSet<_>>().len() as u64;
    (distinct / 4).max(8)
}

/// Hook-call counters shared with the test after [`DeviceMemory`]
/// takes ownership of the policy box.
#[derive(Debug, Default)]
struct Counters {
    admits: AtomicU64,
    removes: AtomicU64,
    picks: AtomicU64,
}

/// Wraps a real policy, counting hook calls and asserting invariant 1
/// at the exact call site: every victim must be evictable in the page
/// table the memory handed over.
#[derive(Debug)]
struct Instrumented {
    inner: Box<dyn EvictionPolicy>,
    counters: Arc<Counters>,
}

impl EvictionPolicy for Instrumented {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn on_admit(&mut self, frame: FrameIdx, page: PageNum, now: Cycle, via_prefetch: bool) {
        self.counters.admits.fetch_add(1, Ordering::Relaxed);
        self.inner.on_admit(frame, page, now, via_prefetch);
    }

    fn on_touch(&mut self, frame: FrameIdx, page: PageNum, prev: Cycle, now: Cycle) {
        self.inner.on_touch(frame, page, prev, now);
    }

    fn on_remove(&mut self, frame: FrameIdx, page: PageNum, info: &PageInfo) {
        self.counters.removes.fetch_add(1, Ordering::Relaxed);
        self.inner.on_remove(frame, page, info);
    }

    fn pick_victim(&mut self, frames: &[Frame], now: Cycle) -> Option<FrameIdx> {
        let v = self.inner.pick_victim(frames, now);
        if let Some(f) = v {
            self.counters.picks.fetch_add(1, Ordering::Relaxed);
            assert!(
                frames.get(f as usize).is_some_and(|fr| fr.evictable(now)),
                "{}: picked victim frame {f} that is not evictable now",
                self.inner.name()
            );
        }
        v
    }
}

fn instrumented(policy: &str) -> (Box<dyn EvictionPolicy>, Arc<Counters>) {
    let counters = Arc::new(Counters::default());
    let inner = eviction::build(policy, 7).expect("known policy");
    (Box::new(Instrumented { inner, counters: counters.clone() }), counters)
}

/// What one drive produced — compared across runs for invariant 4.
#[derive(Debug, PartialEq, Eq)]
struct DriveLog {
    evictions: Vec<PageNum>,
    final_occupancy: u64,
    picks: u64,
}

/// Replay `stream` against a capped [`DeviceMemory`], checking
/// invariants 2, 3 and no-resurrection at every step. Every 7th admit
/// is briefly in-flight (arrival `now + 3`) so `pick_victim` must
/// actually skip non-evictable pages; every 4th is tagged as a
/// prefetch so prefetch-aware/learned exercise their special cases.
fn drive(policy: &str, stream: &[PageNum], capacity: u64) -> DriveLog {
    let (boxed, counters) = instrumented(policy);
    let mut mem = DeviceMemory::with_policy(capacity, boxed);
    let mut model: BTreeSet<PageNum> = BTreeSet::new();
    let mut evictions = Vec::new();
    for (i, &p) in stream.iter().enumerate() {
        let now = i as Cycle;
        if mem.state(p, now).is_some() {
            assert!(model.contains(&p), "{policy}: page {p} resurrected without an admit");
            mem.touch(p, now);
        } else {
            assert!(!model.contains(&p), "{policy}: page {p} vanished without an eviction");
            let arrival = if i % 7 == 0 { now + 3 } else { now };
            let out: Vec<PageNum> =
                mem.admit(p, arrival, i % 4 == 0, now).iter().map(|e| e.page).collect();
            for &e in &out {
                assert!(model.remove(&e), "{policy}: evicted page {e} was not resident");
            }
            evictions.extend(out);
            model.insert(p);
            assert!(
                mem.occupancy() <= capacity,
                "{policy}: occupancy {} exceeds capacity {capacity}",
                mem.occupancy()
            );
        }
        if i % 128 == 0 {
            assert_eq!(mem.occupancy() as usize, model.len(), "{policy}: model diverged");
            let a = counters.admits.load(Ordering::Relaxed);
            let r = counters.removes.load(Ordering::Relaxed);
            assert_eq!(
                a - r,
                mem.occupancy(),
                "{policy}: hook balance broken (admits {a}, removes {r})"
            );
        }
    }
    DriveLog {
        evictions,
        final_occupancy: mem.occupancy(),
        picks: counters.picks.load(Ordering::Relaxed),
    }
}

/// Like [`drive`], but interleaves eager and lazy discards of resident
/// pages — invariant 5: a freed frame stays gone until re-admitted
/// (the no-resurrection assert inside the loop is what would trip).
fn drive_with_discards(policy: &str, stream: &[PageNum], capacity: u64) {
    let (boxed, counters) = instrumented(policy);
    let mut mem = DeviceMemory::with_policy(capacity, boxed);
    let mut model: BTreeSet<PageNum> = BTreeSet::new();
    for (i, &p) in stream.iter().enumerate() {
        let now = i as Cycle;
        if mem.state(p, now).is_some() {
            assert!(model.contains(&p), "{policy}: page {p} resurrected without an admit");
            mem.touch(p, now);
        } else {
            assert!(!model.contains(&p), "{policy}: page {p} vanished without an eviction");
            let out: Vec<PageNum> = mem.admit(p, now, false, now).iter().map(|e| e.page).collect();
            for &e in &out {
                assert!(model.remove(&e), "{policy}: evicted/reclaimed page {e} not resident");
            }
            model.insert(p);
        }
        // Every 5th access, discard the lowest-numbered resident page
        // (deterministic target) — alternating eager and lazy flavors.
        if i % 5 == 0 {
            if let Some(&target) = model.first() {
                if i % 2 == 0 {
                    if mem.discard(target, now).is_some() {
                        model.remove(&target);
                        assert!(
                            mem.state(target, now).is_none(),
                            "{policy}: eagerly discarded page {target} still resident"
                        );
                    }
                } else {
                    // Lazy: the page stays resident until reclaimed at
                    // admission pressure (it then comes back through
                    // admit's return) or the mark is cancelled by a
                    // touch — either way the model stays consistent.
                    mem.discard_lazy(target, now);
                }
            }
        }
        if i % 128 == 0 {
            assert_eq!(mem.occupancy() as usize, model.len(), "{policy}: model diverged");
            let a = counters.admits.load(Ordering::Relaxed);
            let r = counters.removes.load(Ordering::Relaxed);
            assert_eq!(a - r, mem.occupancy(), "{policy}: hook balance broken under discards");
        }
    }
    assert!(mem.discards > 0, "{policy}: the discard interleave never fired");
}

#[test]
fn invariants_hold_for_every_policy_on_dense_and_irregular_streams() {
    for benchmark in DENSE.iter().chain(IRREGULAR) {
        let stream = harvest(benchmark);
        let capacity = pressure_capacity(&stream);
        for policy in ALL_EVICTION_POLICIES {
            let log = drive(policy, &stream, capacity);
            assert!(
                !log.evictions.is_empty(),
                "{policy}/{benchmark}: capacity {capacity} never pressured — vacuous run"
            );
            assert!(log.picks > 0, "{policy}/{benchmark}: pick_victim never consulted");
        }
    }
}

#[test]
fn double_run_is_byte_identical_for_every_policy() {
    // One stream per family is enough: determinism is a property of
    // the policy, the family just varies the index shapes it sees.
    for benchmark in ["atax", "bfs"] {
        let stream = harvest(benchmark);
        let capacity = pressure_capacity(&stream);
        for policy in ALL_EVICTION_POLICIES {
            let a = drive(policy, &stream, capacity);
            let b = drive(policy, &stream, capacity);
            assert_eq!(a, b, "{policy}/{benchmark}: eviction sequence diverged across runs");
        }
    }
}

#[test]
fn discards_never_resurrect_for_every_policy() {
    for benchmark in ["addvectors", "spmv"] {
        let stream = harvest(benchmark);
        let capacity = pressure_capacity(&stream);
        for policy in ALL_EVICTION_POLICIES {
            drive_with_discards(policy, &stream, capacity);
        }
    }
}
