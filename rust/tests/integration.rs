//! Cross-module integration tests: full simulations over every
//! benchmark × policy, checking the accounting invariants that the
//! paper's metrics rest on.

use uvm_prefetch::eval::runner::{run_benchmark, RunOptions};
use uvm_prefetch::types::PAGE_SIZE;
use uvm_prefetch::workloads::{WorkloadFamily, WorkloadRegistry};

fn quick() -> RunOptions {
    RunOptions { scale: 0.25, max_instructions: 400_000, ..Default::default() }
}

/// Core accounting invariants that must hold for any run.
fn check_invariants(name: &str, policy: &str, m: &uvm_prefetch::sim::Metrics) {
    let ctx = format!("{name}/{policy}");
    assert!(m.instructions > 0, "{ctx}: no instructions");
    assert!(m.cycles > 0, "{ctx}: no cycles");
    assert!(m.mem_accesses > 0, "{ctx}: no GMMU accesses");
    // Outcome partition: every GMMU access is hit, coalesced or fault.
    assert_eq!(
        m.page_hits + m.coalesced + m.far_faults,
        m.mem_accesses,
        "{ctx}: outcome partition broken"
    );
    // Interconnect conservation: every byte moved is a demanded fault
    // page or a prefetch transfer.
    assert_eq!(m.bytes_demand, m.far_faults * PAGE_SIZE, "{ctx}: demand bytes");
    assert_eq!(m.bytes_prefetch, m.prefetch_transfers * PAGE_SIZE, "{ctx}: prefetch bytes");
    // Fig 11 series sums to the total traffic.
    let series: u64 = m.pcie_series.iter().map(|&(_, b)| b).sum();
    assert_eq!(series, m.pcie_bytes(), "{ctx}: bucket series conservation");
    // Quality metrics are probabilities.
    for (label, v) in [
        ("hit", m.page_hit_rate()),
        ("acc", m.accuracy()),
        ("cov", m.coverage()),
        ("unity", m.unity()),
    ] {
        assert!((0.0..=1.0).contains(&v), "{ctx}: {label} = {v}");
    }
    // Used prefetches cannot exceed issued ones.
    assert!(m.prefetch_used <= m.prefetch_transfers, "{ctx}: used > issued");
    // IPC bounded by the machine width (28 SMs × 1 IPC).
    assert!(m.ipc() <= 28.0 + 1e-9, "{ctx}: ipc {}", m.ipc());
}

#[test]
fn all_benchmarks_under_demand_paging() {
    let opts = quick();
    for b in WorkloadRegistry::builtin().all() {
        let m = run_benchmark(b, "none", &opts).unwrap();
        check_invariants(b, "none", &m);
        assert_eq!(m.prefetch_transfers, 0, "{b}: demand paging never prefetches");
        assert_eq!(m.coverage(), 0.0, "{b}: nothing covered without prefetch");
    }
}

#[test]
fn all_benchmarks_under_tree_policy() {
    let opts = quick();
    let registry = WorkloadRegistry::builtin();
    let dense = registry.family(WorkloadFamily::Dense);
    for b in registry.all() {
        let m = run_benchmark(b, "tree", &opts).unwrap();
        check_invariants(b, "tree", &m);
        assert!(m.prefetch_transfers > 0, "{b}: tree must prefetch");
        // Block transactions cover most pages only on dense streaming
        // kernels; irregular graph/join traversals fault data-
        // dependently and make no such promise.
        if dense.contains(&b) {
            assert!(
                m.coverage() > 0.5,
                "{b}: block transactions cover most pages: {}",
                m.coverage()
            );
        }
    }
}

#[test]
fn all_benchmarks_under_dl_policy_stride_fallback() {
    let opts = quick();
    for b in WorkloadRegistry::builtin().all() {
        let m = run_benchmark(b, "dl", &opts).unwrap();
        check_invariants(b, "dl", &m);
        assert!(m.prefetch_transfers > 0, "{b}: dl prefetches at least the blocks");
    }
}

#[test]
fn tree_never_loses_to_demand_paging_on_faults() {
    let opts = quick();
    for b in WorkloadRegistry::builtin().all() {
        let none = run_benchmark(b, "none", &opts).unwrap();
        let tree = run_benchmark(b, "tree", &opts).unwrap();
        assert!(
            tree.far_faults <= none.far_faults,
            "{b}: tree {} faults > none {}",
            tree.far_faults,
            none.far_faults
        );
    }
}

#[test]
fn uvmsmart_equals_tree_without_pressure() {
    // Under no oversubscription the adaptive baseline degenerates to
    // the tree prefetcher (paper §7.1) — cycle-exact.
    let opts = quick();
    for b in ["atax", "hotspot", "nw"] {
        let tree = run_benchmark(b, "tree", &opts).unwrap();
        let uvm = run_benchmark(b, "uvmsmart", &opts).unwrap();
        assert_eq!(tree.cycles, uvm.cycles, "{b}");
        assert_eq!(tree.far_faults, uvm.far_faults, "{b}");
    }
}

#[test]
fn prediction_latency_only_hurts() {
    // Fig 10's monotonic story: more prediction overhead can never make
    // the DL policy faster.
    let opts = RunOptions { scale: 0.1, max_instructions: 0, ..Default::default() };
    let fast = uvm_prefetch::eval::runner::run_benchmark_with(
        "pathfinder",
        "dl",
        &opts,
        |mut e| {
            e.runtime.prediction_latency_cycles = 100;
            e
        },
        None,
    )
    .unwrap();
    let slow = uvm_prefetch::eval::runner::run_benchmark_with(
        "pathfinder",
        "dl",
        &opts,
        |mut e| {
            e.runtime.prediction_latency_cycles = 200_000;
            e
        },
        None,
    )
    .unwrap();
    assert!(slow.cycles >= fast.cycles, "slow {} < fast {}", slow.cycles, fast.cycles);
}

#[test]
fn oversubscription_causes_evictions_and_hurts() {
    let opts = RunOptions { scale: 0.25, max_instructions: 1_000_000, ..Default::default() };
    let full = run_benchmark("atax", "tree", &opts).unwrap();
    let tight = uvm_prefetch::eval::runner::run_benchmark_with(
        "atax",
        "tree",
        &opts,
        |mut e| {
            e.sim.device_mem_bytes = 4 << 20; // 4 MB ≪ working set
            e
        },
        None,
    )
    .unwrap();
    assert_eq!(full.evictions, 0, "1 GiB fits the scaled working set");
    assert!(tight.evictions > 0, "4 MB must evict");
    assert!(tight.page_hit_rate() <= full.page_hit_rate());
    check_invariants("atax", "tree-tight", &tight);
}

#[test]
fn trace_gen_roundtrip_feeds_python_schema() {
    // The CSV written by the simulator parses back with the exact
    // column layout data.py expects.
    use uvm_prefetch::eval::runner::run_benchmark_with;
    use uvm_prefetch::sim::TraceWriter;
    let dir = std::env::temp_dir().join(format!("uvm-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.csv");
    let opts = quick();
    let m = run_benchmark_with(
        "nw",
        "tree",
        &opts,
        |e| e,
        Some(TraceWriter::create(&path, 10_000).unwrap()),
    )
    .unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let mut lines = text.lines();
    assert_eq!(
        lines.next().unwrap(),
        "cycle,pc,page,sm,warp,cta,tpc,kernel_id,array_id,miss"
    );
    let mut rows = 0u64;
    for line in lines {
        let cols: Vec<&str> = line.split(',').collect();
        assert_eq!(cols.len(), 10);
        let miss: u8 = cols[9].parse().unwrap();
        assert!(miss <= 1);
        rows += 1;
    }
    assert!(rows > 0 && rows <= 10_000);
    assert!(rows <= m.mem_accesses, "trace rows bounded by GMMU accesses");
    std::fs::remove_dir_all(&dir).unwrap();
}
