//! Property-based tests on coordinator invariants (routing, batching,
//! history/state management), driven by seeded pseudo-random event
//! streams — the offline stand-in for proptest, with explicit seeds so
//! failures reproduce exactly.

use uvm_prefetch::config::{BypassMode, RuntimeConfig};
use uvm_prefetch::coordinator::{FaultEvent, PrefetchCommand, Router};
use uvm_prefetch::predictor::batcher::{Batcher, PendingRequest};
use uvm_prefetch::predictor::history::HistoryTable;
use uvm_prefetch::predictor::{DeltaVocab, FeatTok, Window};
use uvm_prefetch::types::{bb_base, AccessOrigin};
use uvm_prefetch::util::XorShift64;

fn random_event(rng: &mut XorShift64, at: u64) -> FaultEvent {
    FaultEvent {
        at,
        pc: 0x1000 + rng.below(8) * 8,
        page: rng.below(1 << 20),
        origin: AccessOrigin {
            sm: rng.below(28) as u16,
            warp: rng.below(16) as u16,
            cta: rng.below(64) as u32,
            tpc: 0,
            kernel_id: rng.below(2) as u16,
        },
        miss: rng.unit() < 0.3,
        tenant: rng.below(3) as u32,
    }
}

/// Router invariants over arbitrary event streams:
/// * a miss always yields the 15 other pages of its basic block;
/// * a hit never yields migrations, windows, or bypass pages;
/// * any emitted window has exactly `history_len` tokens;
/// * window and bypass are mutually exclusive.
#[test]
fn prop_router_block_and_window_invariants() {
    for seed in 0..20u64 {
        let mut rng = XorShift64::new(seed);
        let vocab = DeltaVocab::synthetic((-4i64..=4).filter(|&d| d != 0).collect(), 10);
        let rcfg = RuntimeConfig {
            history_len: 10,
            bypass: BypassMode::Auto,
            bypass_convergence: 0.9,
            ..Default::default()
        };
        let mut router = Router::new(vocab, &rcfg);
        for i in 0..2_000u64 {
            let ev = random_event(&mut rng, i);
            let out = router.route(&ev);
            if ev.miss {
                assert_eq!(out.block.len(), 15, "seed {seed}: block minus fault page");
                let bb = bb_base(ev.page);
                assert!(out.block.iter().all(|&p| p >= bb && p < bb + 16 && p != ev.page));
                assert!(
                    !(out.window.is_some() && out.bypass_page.is_some()),
                    "seed {seed}: window and bypass are exclusive"
                );
                if let Some((_k, w)) = &out.window {
                    assert_eq!(w.tokens.len(), 10, "seed {seed}");
                }
            } else {
                assert!(out.block.is_empty(), "seed {seed}: hits migrate nothing");
                assert!(out.window.is_none() && out.bypass_page.is_none(), "seed {seed}");
            }
        }
    }
}

/// Batcher conservation: every pushed request comes back out exactly
/// once (full flush, age flush or final flush) and in FIFO order.
#[test]
fn prop_batcher_conserves_requests() {
    for seed in 0..20u64 {
        let mut rng = XorShift64::new(seed ^ 0xb47c);
        let batch_size = 1 + (seed as usize % 7);
        let mut b = Batcher::new(batch_size, 50);
        let mut pushed = Vec::new();
        let mut popped = Vec::new();
        let mut now = 0u64;
        for i in 0..1_000u64 {
            now += rng.below(20);
            if rng.unit() < 0.7 {
                let req = PendingRequest {
                    window: Window {
                        tokens: vec![FeatTok { pc_id: i as i32, page_id: 0, delta_id: 0 }],
                    },
                    anchor_page: i,
                    enqueued_at: now,
                    cluster: 0,
                    pc: 0,
                };
                pushed.push(i);
                if let Some(batch) = b.push(req) {
                    popped.extend(batch.iter().map(|r| r.anchor_page));
                }
            } else if let Some(batch) = b.poll(now) {
                popped.extend(batch.iter().map(|r| r.anchor_page));
            }
        }
        if let Some(batch) = b.flush() {
            popped.extend(batch.iter().map(|r| r.anchor_page));
        }
        assert_eq!(popped, pushed, "seed {seed}: FIFO conservation");
        assert!(b.is_empty());
    }
}

/// History-table state bounds: window length never exceeds capacity,
/// first push of a cluster yields no delta, convergence ∈ (0, 1].
#[test]
fn prop_history_bounds() {
    for seed in 0..20u64 {
        let mut rng = XorShift64::new(seed ^ 0x415);
        let cap = 1 + (seed as usize % 31);
        let mut h: HistoryTable<u64> = HistoryTable::new(cap);
        let mut firsts = std::collections::HashSet::new();
        for i in 0..3_000u64 {
            let key = rng.below(8);
            let tok = h.push(key, 0x10, rng.below(10_000), i);
            if firsts.insert(key) {
                assert!(tok.is_none(), "seed {seed}: first push has no delta");
            }
            let c = h.get(&key).unwrap();
            assert!(c.len() <= cap, "seed {seed}");
            if let Some((_, conv)) = c.dominant_delta() {
                assert!(conv > 0.0 && conv <= 1.0, "seed {seed}: conv {conv}");
            }
        }
    }
}

/// End-to-end service conservation: one Migrate command per miss
/// (whatever the shard count), and predicted pages only after windows
/// fill; nothing is emitted for hit-only streams.
#[test]
fn prop_service_migrates_once_per_miss() {
    use uvm_prefetch::coordinator::{CoordinatorService, SpawnOptions};
    use uvm_prefetch::predictor::ConstantBackend;

    for seed in 0..5u64 {
        for shards in [1usize, 3] {
            let mut rng = XorShift64::new(seed ^ 0x5e2);
            let vocab = DeltaVocab::synthetic(vec![1, 2], 5);
            let rcfg = RuntimeConfig {
                history_len: 5,
                batch_size: 4,
                bypass: BypassMode::Never,
                ..Default::default()
            };
            let backend = Box::new(ConstantBackend { class: 0, n_classes: vocab.n_classes() });
            let sopts = SpawnOptions { shards, max_tenants: 3, ..Default::default() };
            let handle = CoordinatorService::spawn(vocab, backend, &rcfg, &sopts);
            let mut misses = 0u64;
            for i in 0..500u64 {
                let ev = random_event(&mut rng, i);
                misses += ev.miss as u64;
                handle.send(ev).unwrap();
            }
            let report = handle.shutdown();
            let migrates = report
                .commands
                .iter()
                .filter(|c| matches!(c, PrefetchCommand::Migrate { .. }))
                .count() as u64;
            assert_eq!(migrates, misses, "seed {seed} shards {shards}");
            assert_eq!(report.dropped_commands, 0, "seed {seed} shards {shards}");
        }
    }
}
