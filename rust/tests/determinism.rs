//! Determinism guarantees the eval harness rests on: the simulator is
//! bit-reproducible for a fixed seed, and the parallel sweep executor
//! produces results identical to serial execution — so parallelizing
//! the paper tables (PR 1's tentpole) cannot change a single number.

use uvm_prefetch::eval::runner::{run_benchmark, workload_seed, RunOptions};
use uvm_prefetch::eval::sweep::{sweep, CellSpec};

fn tiny() -> RunOptions {
    RunOptions { scale: 0.1, max_instructions: 120_000, ..Default::default() }
}

#[test]
fn same_seed_double_run_has_identical_metrics() {
    let opts = tiny();
    let a = run_benchmark("addvectors", "tree", &opts).unwrap();
    let b = run_benchmark("addvectors", "tree", &opts).unwrap();
    // Full structural equality — every counter, the PCIe series, all.
    assert_eq!(a, b);
    // Byte-identical, not merely equal under PartialEq.
    assert_eq!(format!("{a:?}"), format!("{b:?}"));
}

#[test]
fn workload_seed_is_stable_and_benchmark_dependent() {
    let s1 = workload_seed(0x5eed, "atax");
    let s2 = workload_seed(0x5eed, "atax");
    let s3 = workload_seed(0x5eed, "bicg");
    let s4 = workload_seed(0x1234, "atax");
    assert_eq!(s1, s2, "pure function of (base, benchmark)");
    assert_ne!(s1, s3, "benchmarks draw independent streams");
    assert_ne!(s1, s4, "base seed participates");
}

#[test]
fn parallel_sweep_is_byte_identical_to_serial() {
    let opts = RunOptions { scale: 0.05, max_instructions: 60_000, ..Default::default() };
    let opts_ref = &opts;
    let cells: Vec<CellSpec> = ["addvectors", "atax", "pathfinder"]
        .iter()
        .flat_map(|b| ["tree", "dl"].into_iter().map(move |p| CellSpec::new(b, p, opts_ref)))
        .collect();
    let serial = sweep(&cells, 1).unwrap();
    let parallel = sweep(&cells, 4).unwrap();
    assert_eq!(serial.cells.len(), parallel.cells.len());
    for (s, p) in serial.cells.iter().zip(&parallel.cells) {
        assert_eq!(s.benchmark, p.benchmark);
        assert_eq!(s.prefetcher, p.prefetcher);
        assert_eq!(s.metrics, p.metrics, "{}/{}", s.benchmark, s.prefetcher);
        assert_eq!(
            format!("{:?}", s.metrics),
            format!("{:?}", p.metrics),
            "{}/{}: byte-identical debug form",
            s.benchmark,
            s.prefetcher
        );
    }
}

#[test]
fn oracle_cell_is_deterministic_in_parallel() {
    // The oracle does a recording pass *inside* its cell; two
    // concurrent oracle cells must not interfere (the old
    // Rc<RefCell> + thread_local plumbing is gone).
    let opts = RunOptions { scale: 0.05, max_instructions: 40_000, ..Default::default() };
    let cells = vec![
        CellSpec::new("addvectors", "oracle", &opts),
        CellSpec::new("atax", "oracle", &opts),
        CellSpec::new("addvectors", "oracle", &opts),
    ];
    let out = sweep(&cells, 3).unwrap();
    assert_eq!(out.cells[0].metrics, out.cells[2].metrics, "same cell, same result");
    assert!(out.cells[0].metrics.prefetch_transfers > 0, "oracle actually prefetched");
}
