//! PJRT runtime integration tests — exercised only when `artifacts/`
//! exists (run `make artifacts` first; CI without artifacts skips with
//! a notice). These validate the full AOT contract: HLO text loads,
//! the executable's shapes match the manifest, inference is
//! deterministic, argmax classes are in range, and the train-step
//! executable actually reduces the loss on a repeated batch (the
//! online fine-tune path, paper §7.1).

use std::path::Path;
use std::sync::{Mutex, MutexGuard};
use uvm_prefetch::predictor::{DeltaVocab, LabelledWindow, PredictorBackend, FeatTok, Window};
use uvm_prefetch::runtime::{Manifest, ModelExecutable, PjrtBackend, TensorStore};

/// The PJRT CPU plugin is not robust to several clients being created
/// and destroyed concurrently from sibling test threads (observed
/// SIGSEGV under `cargo test`'s default parallelism); serialize every
/// test that touches it.
static PJRT_LOCK: Mutex<()> = Mutex::new(());

fn pjrt_guard() -> MutexGuard<'static, ()> {
    PJRT_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn artifacts() -> Option<&'static Path> {
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("runtime_pjrt: artifacts/ missing — run `make artifacts` (skipping)");
        None
    }
}

/// The PJRT CPU plugin segfaults intermittently when a client is
/// destroyed and a fresh one created back-to-back (asynchronous
/// teardown races in the plugin) — so all executable-running checks
/// live in this single #[test] sharing ONE client for every load.
/// Pure-file tests (manifest/vocab) stay separate.
#[test]
fn pjrt_end_to_end() {
    let _g = pjrt_guard();
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(dir).unwrap();
    let Ok((name, entry)) =
        manifest.resolve("", "atax").or_else(|_| manifest.resolve("shared", ""))
    else {
        return;
    };
    eprintln!("testing model '{name}'");
    let rt = uvm_prefetch::runtime::PjrtRuntime::cpu().unwrap();
    let vocab = DeltaVocab::from_file(&dir.join(&entry.vocab)).unwrap();
    let exe1 = ModelExecutable::load_with_runtime(&rt, dir, entry).unwrap();
    infer_shapes_and_determinism_impl(&vocab, exe1);
    let exe2 = ModelExecutable::load_with_runtime(&rt, dir, entry).unwrap();
    backend_checks_impl(&vocab, exe2);
}

fn window(vocab: &DeltaVocab, seq_len: usize, seed: i64) -> Window {
    Window {
        tokens: (0..seq_len as i64)
            .map(|i| FeatTok {
                pc_id: ((seed + i) % 3) as i32,
                page_id: ((seed * 11 + i) % 512) as i32,
                delta_id: ((seed + i) % vocab.n_classes() as i64) as i32,
            })
            .collect(),
    }
}

#[test]
fn manifest_and_params_agree() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(dir).unwrap();
    assert!(!manifest.models.is_empty());
    for (name, entry) in &manifest.models {
        let store = TensorStore::load(&dir.join(&entry.params)).unwrap();
        assert_eq!(store.tensors.len(), entry.n_params, "{name}");
        let vocab = DeltaVocab::from_file(&dir.join(&entry.vocab)).unwrap();
        assert_eq!(vocab.n_classes(), entry.n_classes, "{name}");
        assert_eq!(vocab.history_len, entry.seq_len, "{name}");
        assert!(dir.join(&entry.infer_hlo).exists(), "{name}");
    }
}

fn infer_shapes_and_determinism_impl(vocab: &DeltaVocab, mut exe: ModelExecutable) {
    let (b, s, f, c) = (exe.batch, exe.seq_len, exe.n_features, exe.n_classes);
    assert_eq!(f, 3);
    let tokens: Vec<i32> = (0..b * s * f).map(|i| (i % vocab.n_classes().min(3)) as i32).collect();
    let l1 = exe.infer(&tokens).unwrap();
    let l2 = exe.infer(&tokens).unwrap();
    assert_eq!(l1.len(), b * c);
    assert_eq!(l1, l2, "inference must be deterministic");
    assert!(l1.iter().all(|v| v.is_finite()));
    let _ = vocab;
}

fn backend_checks_impl(vocab: &DeltaVocab, exe: ModelExecutable) {
    let seq = exe.seq_len;
    let n_classes = exe.n_classes;
    let has_train = exe.has_train();
    let mut backend = PjrtBackend::new(exe, "revised".into());

    // Partial batch (1 window) and over-full batch (2×batch+3).
    for n in [1usize, 2 * backend.model.batch + 3] {
        let windows: Vec<Window> =
            (0..n as i64).map(|i| window(vocab, seq, i)).collect();
        let classes = backend.predict(&windows);
        assert_eq!(classes.len(), n);
        assert!(classes.iter().all(|&c| (c as usize) < n_classes));
    }

    // A window shorter than seq_len (right-aligned zero padding) must
    // still produce a valid class.
    let mut w = window(vocab, 5, 7);
    w.tokens.truncate(5);
    let classes = backend.predict(&[w]);
    assert_eq!(classes.len(), 1);
    assert!((classes[0] as usize) < n_classes);

    // Online fine-tune: a repeated labelled batch must reduce loss.
    if has_train {
        let batch: Vec<LabelledWindow> = (0..backend.model.train_batch as i64)
            .map(|i| LabelledWindow {
                window: window(vocab, seq, i),
                label: (i % vocab.n_classes() as i64) as i32,
            })
            .collect();
        let l1 = backend.finetune(&batch).expect("train step runs");
        let mut last = l1;
        for _ in 0..5 {
            last = backend.finetune(&batch).unwrap();
        }
        assert!(last < l1, "loss must fall on a repeated batch: {l1} → {last}");
        assert!(backend.model.train_calls >= 6);
    }
}

#[test]
fn vocab_decode_agrees_with_manifest() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(dir).unwrap();
    for (name, entry) in &manifest.models {
        let vocab = DeltaVocab::from_file(&dir.join(&entry.vocab)).unwrap();
        // Last class is OOV; all others decode to a concrete delta.
        for c in 0..vocab.n_classes() as u32 - 1 {
            assert!(
                matches!(vocab.decode(c), uvm_prefetch::predictor::Prediction::Delta(_)),
                "{name} class {c}"
            );
        }
        assert!(matches!(
            vocab.decode(vocab.oov_class()),
            uvm_prefetch::predictor::Prediction::Oov
        ));
    }
}
