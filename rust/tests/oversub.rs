//! Oversubscription subsystem guarantees: ratio semantics (resident
//! fraction of the workload footprint), per-eviction-policy
//! determinism, and the byte-identity anchor — a ratio-1.0 LRU oversub
//! cell is the *same simulation* as the plain `repro eval summary`
//! cell, so the new axis cannot silently move the paper-regime
//! numbers.

use uvm_prefetch::eval::runner::{run_benchmark_with, RunOptions};
use uvm_prefetch::eval::sweep::CellSpec;
use uvm_prefetch::sim::{Metrics, ALL_EVICTION_POLICIES};

fn tiny() -> RunOptions {
    // To completion: every footprint page is touched, so a ratio < 1.0
    // is guaranteed to evict.
    RunOptions { scale: 0.1, max_instructions: 0, ..Default::default() }
}

fn oversub_run(benchmark: &str, prefetcher: &str, ratio: f64, eviction: &str) -> Metrics {
    let ev = eviction.to_string();
    run_benchmark_with(
        benchmark,
        prefetcher,
        &tiny(),
        move |mut e| {
            e.sim.oversub_ratio = ratio;
            e.sim.eviction_policy = ev;
            e
        },
        None,
    )
    .unwrap()
}

#[test]
fn same_seed_double_run_identical_per_eviction_policy() {
    for ev in ALL_EVICTION_POLICIES {
        let a = oversub_run("atax", "tree", 0.5, ev);
        let b = oversub_run("atax", "tree", 0.5, ev);
        assert_eq!(a, b, "{ev}: metrics differ across identical runs");
        assert_eq!(format!("{a:?}"), format!("{b:?}"), "{ev}: byte-identical debug form");
    }
}

#[test]
fn ratio_one_lru_cell_is_byte_identical_to_plain_summary_cell() {
    let opts = tiny();
    for prefetcher in ["none", "tree", "dl"] {
        let plain = CellSpec::new("atax", prefetcher, &opts).run().unwrap();
        let anchored = CellSpec::new("atax", prefetcher, &opts).with_oversub(1.0, "lru").run().unwrap();
        assert_eq!(plain, anchored, "{prefetcher}: ratio 1.0 must be the baseline run");
        assert_eq!(format!("{plain:?}"), format!("{anchored:?}"), "{prefetcher}");
    }
}

#[test]
fn ratio_caps_capacity_to_footprint_fraction_and_evicts() {
    let m = oversub_run("atax", "tree", 0.5, "lru");
    assert!(m.footprint_pages > 1, "footprint computed for oversubscribed runs");
    assert!(
        m.capacity_pages <= m.footprint_pages / 2 + 1,
        "capacity {} !≈ half of footprint {}",
        m.capacity_pages,
        m.footprint_pages
    );
    assert!(m.evictions > 0, "half-footprint residency must evict");
    assert!(m.refaults <= m.far_faults, "refaults are a subset of faults");
    let t = m.thrash_ratio();
    assert!((0.0..=1.0).contains(&t), "thrash ratio {t}");

    let full = run_benchmark_with("atax", "tree", &tiny(), |e| e, None).unwrap();
    assert_eq!(full.evictions, 0, "baseline capacity fits the scaled working set");
    assert!(
        m.page_hit_rate() <= full.page_hit_rate() + 1e-12,
        "pressure cannot improve the hit rate: {} > {}",
        m.page_hit_rate(),
        full.page_hit_rate()
    );
}

#[test]
fn every_eviction_policy_survives_pressure_on_every_prefetcher() {
    for ev in ALL_EVICTION_POLICIES {
        for pf in ["none", "tree", "uvmsmart", "dl"] {
            let m = oversub_run("atax", pf, 0.5, ev);
            assert!(m.instructions > 0, "{ev}/{pf}");
            // dl lazily discards predicted-dead blocks under pressure,
            // so reclaimed marks may absorb part (or even all) of the
            // admission pressure; every other prefetcher must evict.
            if pf == "dl" {
                assert!(
                    m.evictions + m.discards > 0,
                    "{ev}/{pf}: no pressure activity at half footprint"
                );
            } else {
                assert_eq!(m.discards, 0, "{ev}/{pf}: only dl emits discards");
                assert!(m.evictions > 0, "{ev}/{pf}: no evictions at half footprint");
            }
            assert_eq!(
                m.page_hits + m.coalesced + m.far_faults,
                m.mem_accesses,
                "{ev}/{pf}: outcome partition broken under pressure"
            );
        }
    }
}

#[test]
fn invalid_ratio_and_eviction_are_rejected() {
    for bad in [0.0, -0.5, 1.5] {
        let err = run_benchmark_with(
            "addvectors",
            "tree",
            &tiny(),
            move |mut e| {
                e.sim.oversub_ratio = bad;
                e
            },
            None,
        );
        assert!(err.is_err(), "ratio {bad} accepted");
    }
    let err = run_benchmark_with(
        "addvectors",
        "tree",
        &tiny(),
        |mut e| {
            e.sim.oversub_ratio = 0.5;
            e.sim.eviction_policy = "bogus".to_string();
            e
        },
        None,
    );
    assert!(err.is_err(), "unknown eviction policy accepted");
}
