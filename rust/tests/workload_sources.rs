//! Workload-source integration tests: the registry contract, the
//! irregular generators' determinism, and the trace ingest → cache →
//! replay loop (DESIGN.md §10). These pin the API redesign's promises:
//! every registered name builds and places, trace round-trips are
//! byte-identical, parse errors are actionable, and trace cells flow
//! through the sweep with a `source = trace` tag.

use uvm_prefetch::config::SimConfig;
use uvm_prefetch::eval::runner::RunOptions;
use uvm_prefetch::eval::sweep::{bench_eval_json, sweep, CellSpec};
use uvm_prefetch::util::TestDir;
use uvm_prefetch::workloads::{trace, WorkloadFamily, WorkloadRegistry};

/// Every registered builtin builds at a small scale and places every
/// stream inside the simulated machine.
#[test]
fn every_registered_workload_builds_and_places() {
    let cfg = SimConfig::default();
    let registry = WorkloadRegistry::builtin();
    for name in registry.all() {
        let wl = registry.build(name, &cfg, 7, 0.1).unwrap();
        assert!(wl.total_ops > 0, "{name}: empty workload");
        assert!(!wl.tasks.is_empty(), "{name}: no warp streams");
        for t in &wl.tasks {
            assert!(t.sm < cfg.n_sms, "{name}: sm {} out of bounds", t.sm);
            assert!(t.warp < cfg.warps_per_sm, "{name}: warp {} out of bounds", t.warp);
        }
    }
}

/// The irregular trio is seed-deterministic (same seed → identical
/// instance) and seed-sensitive, and its footprints stay bounded so
/// CI-scale runs stay cheap.
#[test]
fn irregular_generators_are_deterministic_and_bounded() {
    let cfg = SimConfig::default();
    let registry = WorkloadRegistry::builtin();
    let irregular = registry.family(WorkloadFamily::Irregular);
    assert_eq!(irregular, vec!["bfs", "spmv", "hash_join"]);
    for name in irregular {
        let a = registry.build(name, &cfg, 11, 0.1).unwrap();
        let b = registry.build(name, &cfg, 11, 0.1).unwrap();
        assert_eq!(a.tasks, b.tasks, "{name}: same seed must reproduce the instance");
        let c = registry.build(name, &cfg, 12, 0.1).unwrap();
        assert_ne!(a.tasks, c.tasks, "{name}: a different seed must change the instance");
        // Bounded footprint: at scale 0.1 the trio stays well under
        // the 1 GiB device memory (32 MiB is ample headroom).
        let bytes = a.footprint_pages() * 4096;
        assert!(bytes <= 32 << 20, "{name}: footprint {bytes} bytes at scale 0.1");
    }
}

/// Serialize → ingest → registry build reproduces the original tasks
/// exactly, and the replay ignores seed/scale (byte-determinism).
#[test]
fn trace_roundtrip_is_byte_identical() {
    let dir = TestDir::new();
    let cfg = SimConfig::default();
    let registry = WorkloadRegistry::builtin();
    let orig = registry.build("atax", &cfg, 3, 0.1).unwrap();

    let raw = dir.file("atax-export.trace");
    trace::write_workload_trace(&orig, &raw).unwrap();
    let report = trace::ingest(&raw, dir.path(), Some("atax-rt"), &cfg).unwrap();
    assert_eq!(report.ops, orig.total_ops);

    let with_traces = WorkloadRegistry::with_trace_dir(dir.path()).unwrap();
    let replay = with_traces.build("trace:atax-rt", &cfg, 999, 4.0).unwrap();
    assert_eq!(replay.tasks, orig.tasks, "replay must reproduce the tasks verbatim");
    assert_eq!(replay.total_ops, orig.total_ops);
    // A second build (different seed/scale again) is identical: traces
    // replay verbatim by design.
    let again = with_traces.build("trace:atax-rt", &cfg, 1, 0.25).unwrap();
    assert_eq!(again.tasks, replay.tasks);
}

/// Malformed traces fail with the file, the 1-based line, and the
/// offending column's name — the serve-replay error convention.
#[test]
fn malformed_trace_errors_name_file_line_and_column() {
    let dir = TestDir::new();
    let bad = dir.file("bad.trace");
    std::fs::write(&bad, "# comment\n0x10 0 0 0 0x1000\n0x10 zz 0 0 0x2000\n").unwrap();
    let err = trace::parse_trace_file(&bad).unwrap_err().to_string();
    assert!(err.contains("bad.trace"), "no file in: {err}");
    assert!(err.contains("line 3"), "no line in: {err}");
    assert!(err.contains("column 2 (sm)"), "no column in: {err}");

    let short = dir.file("short.trace");
    std::fs::write(&short, "0x10 0 0\n").unwrap();
    let err = trace::parse_trace_file(&short).unwrap_err().to_string();
    assert!(err.contains("short.trace") && err.contains("line 1"), "{err}");
    assert!(err.contains("at least 5 fields"), "{err}");

    let empty = dir.file("empty.trace");
    std::fs::write(&empty, "# nothing here\n").unwrap();
    let err = trace::parse_trace_file(&empty).unwrap_err().to_string();
    assert!(err.contains("no trace records"), "{err}");
}

/// Unknown benchmark names fail listing the registered names —
/// including ingested `trace:` entries.
#[test]
fn unknown_names_list_registered_traces() {
    let dir = TestDir::new();
    let cfg = SimConfig::default();
    let wl = WorkloadRegistry::builtin().build("streamtriad", &cfg, 1, 0.05).unwrap();
    let raw = dir.file("st.trace");
    trace::write_workload_trace(&wl, &raw).unwrap();
    trace::ingest(&raw, dir.path(), Some("st"), &cfg).unwrap();

    let registry = WorkloadRegistry::with_trace_dir(dir.path()).unwrap();
    let err = registry.build("nope", &cfg, 1, 1.0).unwrap_err().to_string();
    assert!(err.contains("unknown benchmark 'nope'"), "{err}");
    assert!(err.contains("bfs"), "builtins missing from: {err}");
    assert!(err.contains("trace:st"), "trace entry missing from: {err}");
}

/// An ingested trace runs through the sweep like any builtin: the
/// cell is tagged `source = trace` in `BENCH_eval.json` and its
/// metrics are byte-deterministic across runs.
#[test]
fn sweep_over_ingested_trace_is_tagged_and_deterministic() {
    let dir = TestDir::new();
    let cfg = SimConfig::default();
    let wl = WorkloadRegistry::builtin().build("addvectors", &cfg, 5, 0.1).unwrap();
    let raw = dir.file("av.trace");
    trace::write_workload_trace(&wl, &raw).unwrap();
    trace::ingest(&raw, dir.path(), Some("av"), &cfg).unwrap();

    let opts = RunOptions {
        scale: 0.1,
        max_instructions: 200_000,
        trace_dir: dir.path().display().to_string(),
        ..Default::default()
    };
    let spec = CellSpec::new("trace:av", "tree", &opts);
    let a = sweep(&[spec.clone()], 1).unwrap();
    let b = sweep(&[spec], 2).unwrap();
    assert_eq!(a.cells[0].metrics, b.cells[0].metrics, "trace cells must be deterministic");
    assert_eq!(a.cells[0].source, "trace");
    assert!(a.cells[0].metrics.mem_accesses > 0);

    let json = bench_eval_json(&a);
    let cells = json.get("cells").and_then(|c| c.as_arr()).unwrap();
    assert_eq!(cells[0].get("benchmark").and_then(|v| v.as_str()), Some("trace:av"));
    assert_eq!(cells[0].get("source").and_then(|v| v.as_str()), Some("trace"));
}
