//! Integration suite for the Transformer reference backend (ISSUE 5
//! acceptance): the transformer reaches held-out top-1 ≥ the native
//! backend's on the periodic-stride corpus, same-seed training is
//! byte-deterministic, batched inference is bit-identical to
//! sequential, and checkpoints round-trip through the tensor store —
//! f32 bit-exact, int4 idempotent.

use uvm_prefetch::predictor::engine::featurize_window;
use uvm_prefetch::predictor::nn::OptKind;
use uvm_prefetch::predictor::{
    DeltaVocab, HistoryToken, LabelledWindow, NativeBackend, NativeConfig, TransformerBackend,
    TransformerConfig, Window,
};

const HIST: usize = 6;

/// Same corpus as `rust/tests/native_backend.rs`: a page walk whose
/// delta sequence cycles `1, 1, 3` — fully predictable from the window
/// tail, but capped at 2/3 top-1 for the frequency vote.
fn periodic_stride_corpus(n_tokens: usize) -> (DeltaVocab, Vec<LabelledWindow>) {
    let vocab = DeltaVocab::synthetic(vec![1, 3], HIST);
    let pattern = [1i64, 1, 3];
    let mut page = 0u64;
    let mut toks = Vec::with_capacity(n_tokens);
    for i in 0..n_tokens {
        let delta = pattern[i % pattern.len()];
        page = (page as i64 + delta) as u64;
        toks.push(HistoryToken { pc: 0x40, page, delta });
    }
    let mut windows = Vec::new();
    for i in 0..toks.len() - HIST {
        windows.push(LabelledWindow {
            window: featurize_window(&vocab, &toks[i..i + HIST]),
            label: vocab.encode_delta(toks[i + HIST].delta) as i32,
        });
    }
    (vocab, windows)
}

fn transformer_cfg() -> TransformerConfig {
    TransformerConfig {
        d_model: 16,
        n_heads: 4,
        n_layers: 1,
        d_ff: 32,
        lr: 0.01,
        optimizer: OptKind::Adam,
        seed: 0x5eed,
    }
}

/// Train for `epochs` passes of 16-window mini-batches.
fn train_transformer(
    windows: &[LabelledWindow],
    vocab: &DeltaVocab,
    epochs: usize,
) -> TransformerBackend {
    let mut model = TransformerBackend::init(vocab, &transformer_cfg());
    for _ in 0..epochs {
        for chunk in windows.chunks(16) {
            model.train_batch(chunk);
        }
    }
    model
}

fn trained_native(windows: &[LabelledWindow], vocab: &DeltaVocab) -> NativeBackend {
    let cfg = NativeConfig {
        d_pc: 2,
        d_page: 4,
        d_delta: 8,
        hidden: 16,
        lr: 0.01,
        optimizer: OptKind::Adam,
        seed: 0x5eed,
    };
    let mut model = NativeBackend::init(vocab, &cfg);
    for _ in 0..40 {
        for chunk in windows.chunks(16) {
            model.train_batch(chunk);
        }
    }
    model
}

/// ISSUE 5 acceptance: on the periodic-stride corpus with the same
/// seed, the Transformer reference model reaches top-1 ≥ the native
/// backend (the ceiling must not sit below the distilled model).
#[test]
fn transformer_matches_or_beats_native_on_periodic_stride() {
    let (vocab, windows) = periodic_stride_corpus(320);
    let native = trained_native(&windows, &vocab).top1_accuracy(&windows);
    let mut model = TransformerBackend::init(&vocab, &transformer_cfg());
    let mut transformer = 0.0f64;
    // Train in rounds; the pattern is deterministic, so the model
    // converges well before the cap — the loop bounds runtime, not
    // accuracy.
    for _round in 0..6 {
        for _ in 0..20 {
            for chunk in windows.chunks(16) {
                model.train_batch(chunk);
            }
        }
        transformer = model.top1_accuracy(&windows);
        if transformer >= native.max(0.99) {
            break;
        }
    }
    assert!(transformer >= 0.99, "transformer top-1 {transformer} < 0.99");
    assert!(
        transformer >= native,
        "transformer {transformer} must reach the native backend's {native}"
    );
}

#[test]
fn same_seed_training_is_byte_deterministic() {
    let (vocab, windows) = periodic_stride_corpus(120);
    let a = train_transformer(&windows, &vocab, 4);
    let b = train_transformer(&windows, &vocab, 4);
    assert_eq!(a.params(), b.params(), "identical seed + data ⇒ identical weights");

    let dir = uvm_prefetch::util::TestDir::new();
    let (pa, pb) = (dir.file("a.bin"), dir.file("b.bin"));
    a.save(&pa, false).unwrap();
    b.save(&pb, false).unwrap();
    assert_eq!(
        std::fs::read(&pa).unwrap(),
        std::fs::read(&pb).unwrap(),
        "saved artifacts must be byte-identical"
    );
}

/// The PR 4 guarantee, extended to the transformer: batching must
/// never change an answer — bit for bit, on a trained model.
#[test]
fn batched_predict_matches_sequential_on_trained_model() {
    let (vocab, windows) = periodic_stride_corpus(200);
    let model = train_transformer(&windows, &vocab, 6);
    let ws: Vec<Window> = windows.iter().map(|lw| lw.window.clone()).collect();
    let batched = model.logits_batch(&ws);
    let sequential: Vec<f32> = ws.iter().flat_map(|w| model.logits_one(w)).collect();
    assert_eq!(batched, sequential, "batched logits diverged from sequential");
    let classes = model.predict_batch(&ws);
    let one_by_one: Vec<u32> = ws.iter().map(|w| model.predict_one(w)).collect();
    assert_eq!(classes, one_by_one);
}

#[test]
fn save_load_roundtrip_predicts_identically() {
    let (vocab, windows) = periodic_stride_corpus(150);
    let model = train_transformer(&windows, &vocab, 4);
    let dir = uvm_prefetch::util::TestDir::new();
    let path = dir.file("m.transformer.params.bin");
    model.save(&path, false).unwrap();
    let back = TransformerBackend::load(&path, &TransformerConfig::default()).unwrap();
    assert_eq!(back.params(), model.params(), "f32 round trip must be bit-exact");
    let ws: Vec<Window> = windows.iter().map(|lw| lw.window.clone()).collect();
    assert_eq!(
        back.predict_batch(&ws),
        model.predict_batch(&ws),
        "loaded model must predict identically"
    );
}

/// ISSUE 5 acceptance: the int4-quantized path round-trips too —
/// quantization is a projection, so save→load→save→load is a fixed
/// point and predictions are bit-identical from there on.
#[test]
fn int4_save_load_roundtrip_is_idempotent() {
    let (vocab, windows) = periodic_stride_corpus(150);
    let model = train_transformer(&windows, &vocab, 4);
    let dir = uvm_prefetch::util::TestDir::new();
    let (p1, p2) = (dir.file("m.int4.bin"), dir.file("m2.int4.bin"));
    model.save(&p1, true).unwrap();
    let q1 = TransformerBackend::load(&p1, &TransformerConfig::default()).unwrap();
    q1.save(&p2, true).unwrap();
    let q2 = TransformerBackend::load(&p2, &TransformerConfig::default()).unwrap();
    assert_eq!(q1.params(), q2.params(), "int4 round trip must be idempotent");
    let ws: Vec<Window> = windows.iter().map(|lw| lw.window.clone()).collect();
    assert_eq!(q1.predict_batch(&ws), q2.predict_batch(&ws));
    // The shape survives quantization exactly (meta stays f32).
    assert_eq!(q1.seq_len(), model.seq_len());
    assert_eq!(q1.n_heads(), model.n_heads());
    assert_eq!(q1.n_layers(), model.n_layers());
    // Per-tensor scaled int4: the error is bounded by absmax/7 over
    // the whole vector (a fortiori per tensor, whose absmax is no
    // larger), and exact zeros survive.
    let absmax = model.params().iter().fold(0.0f32, |m, v| m.max(v.abs()));
    for (a, b) in model.params().iter().zip(q1.params()) {
        assert!(
            (a - b).abs() <= absmax / 7.0 + 1e-6,
            "quant error {} for weight {a} (absmax {absmax})",
            (a - b).abs()
        );
        if *a == 0.0 {
            assert_eq!(*b, 0.0, "zero weights must survive quantization");
        }
    }
}

/// Attention introspection surface: maps are proper distributions and
/// deterministic for a fixed seed (the `repro analyze` contract).
#[test]
fn attention_maps_deterministic_and_normalized() {
    let (vocab, windows) = periodic_stride_corpus(120);
    let a = train_transformer(&windows, &vocab, 3);
    let b = train_transformer(&windows, &vocab, 3);
    let (la, ma) = a.attention_one(&windows[0].window);
    let (lb, mb) = b.attention_one(&windows[0].window);
    assert_eq!(la, lb);
    assert_eq!(ma, mb, "attention maps must be deterministic");
    assert_eq!(ma.len(), a.n_layers() * a.n_heads() * HIST * HIST);
    for row in ma.chunks_exact(HIST) {
        assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-4);
    }
}
