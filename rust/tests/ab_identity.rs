//! A/B byte-identity gate for the hot-path refactor (DESIGN.md §12).
//!
//! The dense-frame-table / allocation-free fault loop rewrite promises
//! **byte-identical metrics** — not "close", identical. This suite
//! pins that promise to committed fixtures: one dense (`addvectors`)
//! and one irregular (`spmv`) workload, at oversubscription ratios
//! {1.0, 0.25}, across **all five** eviction policies, with the tree
//! prefetcher so the prefetch-admit and unused-prefetch-eviction paths
//! are on the line too. Every integer counter the simulator emits must
//! match `ci/ab_fixtures.json` exactly.
//!
//! The fixture follows the repo's bootstrap convention (`repro
//! golden`): while `"bootstrap": true` (no toolchain where the gate
//! was authored), the grid instead runs **twice** and both runs must
//! agree bit-for-bit — then the measured candidates are printed.
//! Pin real numbers with `UVM_UPDATE_AB=1 cargo test -q ab_identity`
//! and commit the diff; any later mismatch means the refactor changed
//! observable behavior.

use std::path::PathBuf;
use uvm_prefetch::eval::runner::RunOptions;
use uvm_prefetch::eval::sweep::CellSpec;
use uvm_prefetch::sim::eviction::ALL_EVICTION_POLICIES;
use uvm_prefetch::sim::Metrics;
use uvm_prefetch::util::{Json, TestDir};

const AB_SCHEMA: &str = "ab_fixtures/v1";
const BENCHMARKS: &[&str] = &["addvectors", "spmv"];
const RATIOS: &[f64] = &[1.0, 0.25];

fn fixture_path() -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../ci/ab_fixtures.json"))
}

/// The tiny oversub regime (mirrors the oversub module's own tests):
/// small enough for CI, big enough that ratio 0.25 churns evictions.
fn tiny() -> RunOptions {
    RunOptions { scale: 0.05, max_instructions: 30_000, ..Default::default() }
}

/// The pinned grid, in a stable order: benchmark-fastest under
/// eviction under ratio (the oversub sweep's axis nesting).
fn ab_cells() -> Vec<(String, CellSpec)> {
    let opts = tiny();
    let mut cells = Vec::new();
    for &ratio in RATIOS {
        for ev in ALL_EVICTION_POLICIES {
            for b in BENCHMARKS {
                let spec = CellSpec::new(b, "tree", &opts).with_oversub(ratio, ev);
                cells.push((format!("{b}/tree/r{ratio:.2}/{ev}"), spec));
            }
        }
    }
    cells
}

/// Every integer counter of [`Metrics`], by stable name — the full
/// observable surface minus the float derivations (which are pure
/// functions of these) and the PCIe series (summarized by length and
/// byte totals, which pin it transitively since bucket boundaries are
/// deterministic in the cycle counters).
fn counters(m: &Metrics) -> Vec<(&'static str, u64)> {
    vec![
        ("instructions", m.instructions),
        ("cycles", m.cycles),
        ("mem_accesses", m.mem_accesses),
        ("page_hits", m.page_hits),
        ("coalesced", m.coalesced),
        ("far_faults", m.far_faults),
        ("tlb_hits", m.tlb_hits),
        ("tlb_misses", m.tlb_misses),
        ("prefetch_transfers", m.prefetch_transfers),
        ("prefetch_used", m.prefetch_used),
        ("bytes_demand", m.bytes_demand),
        ("bytes_prefetch", m.bytes_prefetch),
        ("pcie_series_len", m.pcie_series.len() as u64),
        ("pcie_series_bytes", m.pcie_series.iter().map(|&(_, b)| b).sum()),
        ("evictions", m.evictions),
        ("evicted_unused_prefetches", m.evicted_unused_prefetches),
        ("refaults", m.refaults),
        ("capacity_pages", m.capacity_pages),
        ("footprint_pages", m.footprint_pages),
        ("discards", m.discards),
        ("lazy_discard_reclaims", m.lazy_discard_reclaims),
        ("advised_pages", m.advised_pages),
    ]
}

fn measure() -> Vec<(String, Metrics)> {
    ab_cells()
        .into_iter()
        .map(|(key, spec)| {
            let m = spec.run().unwrap_or_else(|e| panic!("{key}: cell failed: {e}"));
            (key, m)
        })
        .collect()
}

fn fixture_json(measured: &[(String, Metrics)]) -> Json {
    let cells: std::collections::BTreeMap<String, Json> = measured
        .iter()
        .map(|(key, m)| {
            let fields =
                counters(m).into_iter().map(|(k, v)| (k, Json::num(v as f64))).collect();
            (key.clone(), Json::obj(fields))
        })
        .collect();
    Json::obj(vec![
        ("schema", Json::str(AB_SCHEMA)),
        ("bootstrap", Json::Bool(false)),
        ("cells", Json::Obj(cells)),
    ])
}

#[test]
fn grid_shape_is_pinned() {
    let cells = ab_cells();
    // 2 ratios × 5 eviction policies × 2 benchmarks.
    assert_eq!(cells.len(), 20);
    assert_eq!(cells[0].0, "addvectors/tree/r1.00/lru");
    assert_eq!(cells.last().unwrap().0.as_str(), "spmv/tree/r0.25/learned");
    // u64 counters survive the f64 JSON round-trip only below 2^53;
    // tiny cells sit far under that, but keep the guard explicit.
    for (key, _) in &cells {
        assert!(key.contains("/tree/"), "grid runs the tree prefetcher everywhere");
    }
}

#[test]
fn metrics_match_committed_fixtures_byte_for_byte() {
    let path = fixture_path();
    let measured = measure();

    if std::env::var("UVM_UPDATE_AB").map(|v| v == "1").unwrap_or(false) {
        fixture_json(&measured).write_file(&path).expect("write ab fixtures");
        println!("ab_identity: pinned {} cells to {}", measured.len(), path.display());
        return;
    }

    let doc = match Json::parse_file(&path) {
        Ok(d) => d,
        Err(e) => {
            panic!("{}: unreadable A/B fixture ({e}); commit one (see module docs)", path.display())
        }
    };
    assert_eq!(
        doc.get("schema").and_then(Json::as_str),
        Some(AB_SCHEMA),
        "{}: wrong fixture schema",
        path.display()
    );

    if doc.get("bootstrap").and_then(Json::as_bool).unwrap_or(false) {
        // No pinned numbers yet: gate determinism instead (the same
        // double-run contract the refactor must preserve), and print
        // the candidates a maintainer would commit.
        let second = measure();
        for ((key, a), (_, b)) in measured.iter().zip(&second) {
            assert_eq!(a, b, "{key}: nondeterministic across identical runs");
        }
        println!(
            "ab_identity: bootstrap determinism gate OK ({} cells). Candidates:",
            measured.len()
        );
        for (key, m) in &measured {
            let cs: Vec<String> =
                counters(m).into_iter().map(|(k, v)| format!("{k}={v}")).collect();
            println!("  {key}: {}", cs.join(" "));
        }
        println!("ab_identity: pin with `UVM_UPDATE_AB=1 cargo test -q ab_identity`");
        return;
    }

    let cells = doc.get("cells").expect("fixture has cells");
    let mut mismatches = Vec::new();
    for (key, m) in &measured {
        let Some(golden) = cells.get(key) else {
            mismatches.push(format!("{key}: missing from fixtures (re-pin with UVM_UPDATE_AB=1)"));
            continue;
        };
        for (field, v) in counters(m) {
            match golden.get(field).and_then(Json::as_f64) {
                Some(g) if g == v as f64 => {}
                Some(g) => mismatches
                    .push(format!("{key}: {field} = {v}, fixture {g} — NOT byte-identical")),
                None => mismatches.push(format!("{key}: fixture field '{field}' missing")),
            }
        }
    }
    assert!(
        mismatches.is_empty(),
        "A/B identity gate FAILED — {} mismatch(es):\n  {}",
        mismatches.len(),
        mismatches.join("\n  ")
    );
}

/// Telemetry is a strict observer: attaching a sink must not perturb a
/// single counter. Run the thorniest cells of the pinned grid (the
/// churny 0.25-ratio ones exercise eviction, unused-prefetch, and
/// refault resolution) with and without a sink and demand equality of
/// the *entire* `Metrics` struct — same oracle the refactor gate uses.
#[test]
fn telemetry_attach_leaves_metrics_byte_identical() {
    let dir = TestDir::new();
    let opts = tiny();
    for (i, &ratio) in RATIOS.iter().enumerate() {
        for b in BENCHMARKS {
            let spec = CellSpec::new(b, "tree", &opts).with_oversub(ratio, "lru");
            let plain = spec.run().expect("telemetry-off cell");
            let out = dir.file(&format!("tel_{i}_{b}.json"));
            let observed = spec.run_with_telemetry(Some(out.as_path())).expect("telemetry-on cell");
            assert_eq!(plain, observed, "{b}/r{ratio:.2}: telemetry perturbed the simulation");
            assert!(out.exists(), "{b}/r{ratio:.2}: sink wrote no file");
        }
    }
}

/// The telemetry file itself is deterministic: two identical runs must
/// produce byte-for-byte equal output (BTreeMap-backed JSON, simulated
/// timestamps only — no wall clock anywhere in the schema).
#[test]
fn telemetry_file_is_byte_deterministic_across_runs() {
    let dir = TestDir::new();
    let opts = tiny();
    let spec = CellSpec::new("spmv", "tree", &opts).with_oversub(0.25, "lru");
    let (a, b) = (dir.file("run_a.json"), dir.file("run_b.json"));
    let ma = spec.run_with_telemetry(Some(a.as_path())).expect("first run");
    let mb = spec.run_with_telemetry(Some(b.as_path())).expect("second run");
    assert_eq!(ma, mb, "metrics nondeterministic across identical runs");
    let (bytes_a, bytes_b) = (std::fs::read(&a).unwrap(), std::fs::read(&b).unwrap());
    assert!(!bytes_a.is_empty(), "telemetry file is empty");
    assert_eq!(bytes_a, bytes_b, "telemetry file differs across identical runs");
    // Sanity: the file parses and carries the v1 schema.
    let doc = Json::parse_file(&a).expect("telemetry file parses");
    assert_eq!(doc.get("schema").and_then(Json::as_str), Some("telemetry/v1"));
}
