//! Integration suite for the precision-tiered kernel layer (ISSUE 6
//! acceptance): quantized serving accuracy on a trained model, the
//! exact tier's bit stability across tier switches, and the backend
//! factory's named-flag precision errors end to end.

use std::collections::BTreeMap;
use uvm_prefetch::predictor::engine::featurize_window;
use uvm_prefetch::predictor::nn::OptKind;
use uvm_prefetch::predictor::vocab::VocabFile;
use uvm_prefetch::predictor::{
    factory, DeltaVocab, HistoryToken, LabelledWindow, NativeBackend, NativeConfig, Precision,
    PredictorBackend, Window,
};
use uvm_prefetch::runtime::{Manifest, ModelEntry};
use uvm_prefetch::util::TestDir;

const HIST: usize = 6;

/// The same periodic `1, 1, 3` page walk as the native-backend suite:
/// fully predictable from the window tail, so a trained model clears
/// 99% top-1 and any quantization damage shows up as lost points.
fn periodic_stride_corpus(n_tokens: usize) -> (DeltaVocab, Vec<LabelledWindow>) {
    let vocab = DeltaVocab::synthetic(vec![1, 3], HIST);
    let pattern = [1i64, 1, 3];
    let mut page = 0u64;
    let mut toks = Vec::with_capacity(n_tokens);
    for i in 0..n_tokens {
        let delta = pattern[i % pattern.len()];
        page = (page as i64 + delta) as u64;
        toks.push(HistoryToken { pc: 0x40, page, delta });
    }
    let mut windows = Vec::new();
    for i in 0..toks.len() - HIST {
        windows.push(LabelledWindow {
            window: featurize_window(&vocab, &toks[i..i + HIST]),
            label: vocab.encode_delta(toks[i + HIST].delta) as i32,
        });
    }
    (vocab, windows)
}

fn trained_model(windows: &[LabelledWindow], vocab: &DeltaVocab) -> NativeBackend {
    let cfg = NativeConfig {
        d_pc: 2,
        d_page: 4,
        d_delta: 8,
        hidden: 16,
        lr: 0.01,
        optimizer: OptKind::Adam,
        seed: 0x5eed,
    };
    let mut model = NativeBackend::init(vocab, &cfg);
    for _ in 0..40 {
        for chunk in windows.chunks(16) {
            model.train_batch(chunk);
        }
    }
    model
}

fn top1(model: &NativeBackend, windows: &[LabelledWindow]) -> f64 {
    let ws: Vec<Window> = windows.iter().map(|lw| lw.window.clone()).collect();
    let hits = model
        .predict_batch(&ws)
        .iter()
        .zip(windows)
        .filter(|(p, lw)| **p == lw.label as u32)
        .count();
    hits as f64 / windows.len().max(1) as f64
}

/// Register a saved checkpoint in a minimal manifest so the factory
/// can resolve it like a real `repro train` artifact.
fn register(dir: &TestDir, vocab_file: &VocabFile, params_rel: &str, n_params: usize) {
    vocab_file.to_json().write_file(&dir.path().join("bench.vocab.json")).unwrap();
    let mut models = BTreeMap::new();
    models.insert(
        "bench".to_string(),
        ModelEntry {
            infer_hlo: String::new(),
            train_hlo: None,
            params: params_rel.to_string(),
            vocab: "bench.vocab.json".to_string(),
            batch: 16,
            train_batch: 16,
            seq_len: HIST,
            n_features: 3,
            n_classes: 3,
            n_params,
            arch: "native".to_string(),
        },
    );
    Manifest { version: 1, models }.save(dir.path()).unwrap();
}

fn vocab_file() -> VocabFile {
    VocabFile {
        deltas: vec![1, 3],
        pcs: vec![],
        page_buckets: 1024,
        dominant_delta: 1,
        convergence: 0.0,
        history_len: HIST,
    }
}

/// Acceptance: on the periodic-stride corpus, every non-exact serving
/// tier of a trained model stays within one point of f32 top-1.
#[test]
fn quantized_and_fast_top1_within_one_point_of_f32() {
    let (vocab, windows) = periodic_stride_corpus(320);
    let mut model = trained_model(&windows, &vocab);
    let exact = top1(&model, &windows);
    assert!(exact >= 0.99, "trained f32 top-1 {exact} < 0.99");

    model.set_precision(Precision::Fast).unwrap();
    let fast = top1(&model, &windows);
    assert!((exact - fast).abs() <= 0.01, "fast top-1 {fast} vs exact {exact}");

    let dir = TestDir::new();
    let p4 = dir.file("m.int4.bin");
    model.save(&p4, true).unwrap();
    for precision in [Precision::Int8, Precision::Int4] {
        let q = NativeBackend::load_with_precision(&p4, &NativeConfig::default(), precision)
            .unwrap();
        let quant = top1(&q, &windows);
        assert!(
            (exact - quant).abs() <= 0.01,
            "{} top-1 {quant} strays > 1 point from exact {exact}",
            precision.as_str()
        );
    }
}

/// Switching tiers never contaminates the exact path: logits after a
/// fast round trip are bit-identical to before, and the fast tier is
/// batch-order invariant.
#[test]
fn exact_tier_survives_tier_switches_bitwise() {
    let (vocab, windows) = periodic_stride_corpus(150);
    let mut model = trained_model(&windows, &vocab);
    let ws: Vec<Window> = windows.iter().map(|lw| lw.window.clone()).collect();
    let before = model.logits_batch(&ws);

    model.set_precision(Precision::Fast).unwrap();
    let fast = model.logits_batch(&ws);
    let fast_seq: Vec<f32> = ws.iter().flat_map(|w| model.logits_one(w)).collect();
    assert_eq!(fast, fast_seq, "fast tier batched == sequential");

    model.set_precision(Precision::Exact).unwrap();
    assert_eq!(model.logits_batch(&ws), before, "exact logits changed after a tier round trip");
}

/// The factory serves the quantized tiers from a registered artifact —
/// preferring the `.int4.params.bin` sibling — and rejects an f32-only
/// checkpoint with an error naming `--precision`.
#[test]
fn factory_resolves_quantized_siblings_and_names_the_flag() {
    let (vocab, windows) = periodic_stride_corpus(150);
    let model = trained_model(&windows, &vocab);
    let dir = TestDir::new();
    model.save(&dir.path().join("bench.native.params.bin"), false).unwrap();
    register(&dir, &vocab_file(), "bench.native.params.bin", model.params().len());
    let artifacts = dir.path().to_string_lossy().into_owned();

    // f32-only store + int4 tier → named-flag error, not a panic.
    let err = factory::load_model_backend(&artifacts, "", "bench", "native", Precision::Int4, "t")
        .unwrap_err()
        .to_string();
    assert!(err.contains("--precision int4"), "{err}");

    // With the sibling store on disk the same spec serves integers.
    model.save(&dir.path().join("bench.native.int4.params.bin"), true).unwrap();
    let (loaded_vocab, mut backend) =
        factory::load_model_backend(&artifacts, "", "bench", "native", Precision::Int4, "t")
            .unwrap();
    assert_eq!(loaded_vocab.n_classes(), 3);
    assert_eq!(backend.info().precision, Precision::Int4);
    let ws: Vec<Window> = windows.iter().map(|lw| lw.window.clone()).collect();
    let preds = backend.predict(&ws);
    assert_eq!(preds.len(), ws.len());

    // The exact tier through the same factory still reads the f32
    // store and matches the in-memory model bitwise.
    let (_, mut exact) =
        factory::load_model_backend(&artifacts, "", "bench", "native", Precision::Exact, "t")
            .unwrap();
    assert_eq!(exact.predict(&ws), model.predict_batch(&ws));
}
