//! Integration suite for the native pure-Rust learned backend
//! (ISSUE 3 acceptance): same-seed byte determinism, learning a
//! synthetic stride pattern past the frequency-vote floor, save→load
//! identity, `--backend` CLI validation, and the online fine-tune path
//! through the dl prefetcher.

use uvm_prefetch::config::{BypassMode, PredictorBackendKind, RuntimeConfig};
use uvm_prefetch::eval::runner::RunOptions;
use uvm_prefetch::predictor::engine::featurize_window;
use uvm_prefetch::predictor::nn::OptKind;
use uvm_prefetch::predictor::{
    DeltaVocab, HistoryToken, LabelledWindow, NativeBackend, NativeConfig, PredictorBackend,
    PredictorEngine, StrideBackend, Window,
};
use uvm_prefetch::prefetch::dl::DlPrefetcher;
use uvm_prefetch::types::AccessOrigin;

const HIST: usize = 6;

/// A page walk whose delta sequence cycles `1, 1, 3`: the majority
/// vote is always delta 1 (4-of-6 in every window), so the stride
/// backend caps at 2/3 top-1 while the pattern is fully predictable
/// from the window tail — the gap the learned model must close.
fn periodic_stride_corpus(n_tokens: usize) -> (DeltaVocab, Vec<LabelledWindow>) {
    let vocab = DeltaVocab::synthetic(vec![1, 3], HIST);
    let pattern = [1i64, 1, 3];
    let mut page = 0u64;
    let mut toks = Vec::with_capacity(n_tokens);
    for i in 0..n_tokens {
        let delta = pattern[i % pattern.len()];
        page = (page as i64 + delta) as u64;
        toks.push(HistoryToken { pc: 0x40, page, delta });
    }
    let mut windows = Vec::new();
    for i in 0..toks.len() - HIST {
        windows.push(LabelledWindow {
            window: featurize_window(&vocab, &toks[i..i + HIST]),
            label: vocab.encode_delta(toks[i + HIST].delta) as i32,
        });
    }
    (vocab, windows)
}

fn trained_model(windows: &[LabelledWindow], vocab: &DeltaVocab) -> NativeBackend {
    let cfg = NativeConfig {
        d_pc: 2,
        d_page: 4,
        d_delta: 8,
        hidden: 16,
        lr: 0.01,
        optimizer: OptKind::Adam,
        seed: 0x5eed,
    };
    let mut model = NativeBackend::init(vocab, &cfg);
    for _ in 0..40 {
        for chunk in windows.chunks(16) {
            model.train_batch(chunk);
        }
    }
    model
}

fn stride_top1(windows: &[LabelledWindow], vocab: &DeltaVocab) -> f64 {
    let mut stride = StrideBackend::new(vocab.n_classes(), HIST);
    let ws: Vec<Window> = windows.iter().map(|lw| lw.window.clone()).collect();
    let hits = stride
        .predict(&ws)
        .iter()
        .zip(windows)
        .filter(|(p, lw)| **p == lw.label as u32)
        .count();
    hits as f64 / windows.len() as f64
}

/// Acceptance: the trained native backend beats the stride backend's
/// top-1 accuracy on the synthetic stride pattern, and clears 99%.
#[test]
fn native_learns_periodic_stride_past_the_frequency_vote() {
    let (vocab, windows) = periodic_stride_corpus(320);
    let model = trained_model(&windows, &vocab);
    let native = model.top1_accuracy(&windows);
    let stride = stride_top1(&windows, &vocab);
    assert!(native >= 0.99, "native top-1 {native} < 0.99");
    assert!(
        stride < 0.75,
        "stride backend should cap near 2/3 on the periodic pattern, got {stride}"
    );
    assert!(native > stride, "native {native} must beat stride {stride}");
}

/// Acceptance (ISSUE 4): the batched forward used by the serving
/// coordinator is bit-identical to the sequential path on a *trained*
/// model over a real corpus — batching must never change an answer.
#[test]
fn batched_predict_matches_sequential_on_trained_model() {
    let (vocab, windows) = periodic_stride_corpus(300);
    let model = trained_model(&windows, &vocab);
    let ws: Vec<Window> = windows.iter().map(|lw| lw.window.clone()).collect();
    let batched = model.logits_batch(&ws);
    let sequential: Vec<f32> = ws.iter().flat_map(|w| model.logits_one(w)).collect();
    assert_eq!(batched, sequential, "batched logits diverged from sequential");
    let classes = model.predict_batch(&ws);
    let one_by_one: Vec<u32> = ws.iter().map(|w| model.predict_one(w)).collect();
    assert_eq!(classes, one_by_one);
}

#[test]
fn same_seed_training_is_byte_deterministic() {
    let (vocab, windows) = periodic_stride_corpus(120);
    let a = trained_model(&windows, &vocab);
    let b = trained_model(&windows, &vocab);
    assert_eq!(a.params(), b.params(), "identical seed + data ⇒ identical weights");

    let dir = uvm_prefetch::util::TestDir::new();
    let (pa, pb) = (dir.file("a.bin"), dir.file("b.bin"));
    a.save(&pa, false).unwrap();
    b.save(&pb, false).unwrap();
    assert_eq!(
        std::fs::read(&pa).unwrap(),
        std::fs::read(&pb).unwrap(),
        "saved artifacts must be byte-identical"
    );
}

#[test]
fn save_load_roundtrip_predicts_identically() {
    let (vocab, windows) = periodic_stride_corpus(150);
    let mut model = trained_model(&windows, &vocab);
    let dir = uvm_prefetch::util::TestDir::new();
    let path = dir.file("m.native.params.bin");
    model.save(&path, false).unwrap();
    let mut back = NativeBackend::load(&path, &NativeConfig::default()).unwrap();
    assert_eq!(back.params(), model.params());
    let ws: Vec<Window> = windows.iter().map(|lw| lw.window.clone()).collect();
    assert_eq!(back.predict(&ws), model.predict(&ws), "loaded model must predict identically");
}

/// ISSUE 5 satellite: the int4 path is wired into real use. Saving a
/// trained native model quantized, loading it back, and re-saving must
/// be *idempotent* (quantization is a projection), and the quantized
/// model still answers with valid classes.
#[test]
fn int4_save_load_roundtrip_is_idempotent() {
    let (vocab, windows) = periodic_stride_corpus(150);
    let model = trained_model(&windows, &vocab);
    let dir = uvm_prefetch::util::TestDir::new();
    let (p1, p2) = (dir.file("m.int4.bin"), dir.file("m2.int4.bin"));
    model.save(&p1, true).unwrap();
    let q1 = NativeBackend::load(&p1, &NativeConfig::default()).unwrap();
    q1.save(&p2, true).unwrap();
    let q2 = NativeBackend::load(&p2, &NativeConfig::default()).unwrap();
    assert_eq!(q1.params(), q2.params(), "int4 round trip must be idempotent");
    let ws: Vec<Window> = windows.iter().map(|lw| lw.window.clone()).collect();
    assert_eq!(q1.predict_batch(&ws), q2.predict_batch(&ws));
    // Per-tensor scaled int4: zero stays exact and the error is
    // bounded by absmax/7 over the whole vector (a fortiori per
    // tensor, whose absmax is no larger).
    let absmax = model.params().iter().fold(0.0f32, |m, v| m.max(v.abs()));
    for (a, b) in model.params().iter().zip(q1.params()) {
        assert!(
            (a - b).abs() <= absmax / 7.0 + 1e-6,
            "quant error {} for weight {a} (absmax {absmax})",
            (a - b).abs()
        );
        if *a == 0.0 {
            assert_eq!(*b, 0.0, "zero weights must survive quantization");
        }
    }
}

#[test]
fn backend_cli_axis_validates_names() {
    let mut opts = RunOptions::default();
    for ok in ["", "stride", "native", "transformer", "pjrt"] {
        opts.backend = ok.to_string();
        assert!(opts.backend_kind().is_ok(), "'{ok}' must parse");
    }
    opts.backend = "lstm".to_string();
    let err = opts.backend_kind().unwrap_err().to_string();
    assert!(err.contains("stride | native | transformer | pjrt"), "{err}");

    // The kind also round-trips through the runtime-config JSON.
    let kind = PredictorBackendKind::Native { artifacts: "m".into(), model: "x".into() };
    let cfg = RuntimeConfig { backend: kind.clone(), ..Default::default() };
    let text = cfg.to_json().to_string();
    let back = RuntimeConfig::from_json(&uvm_prefetch::util::Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back.backend, kind);
}

/// The FinetuneScheduler/Batcher machinery finally drives a backend
/// that learns: labels harvested from the access stream reach
/// `NativeBackend::finetune`, which returns a real (finite) loss.
#[test]
fn online_finetune_records_real_losses_through_dl() {
    let rcfg = RuntimeConfig {
        history_len: 3,
        batch_size: 4,
        finetune_interval_insts: 10,
        finetune_batch: 4,
        bypass: BypassMode::Never,
        ..Default::default()
    };
    let vocab = DeltaVocab::synthetic(vec![1, 2], 3);
    let native = NativeBackend::init(
        &vocab,
        &NativeConfig { d_pc: 2, d_page: 2, d_delta: 4, hidden: 8, ..Default::default() },
    );
    let engine = PredictorEngine::new(Box::new(native), vocab);
    let mut p = DlPrefetcher::new(engine, &rcfg);
    let origin = AccessOrigin { sm: 0, warp: 0, cta: 0, tpc: 0, kernel_id: 0 };
    for i in 0..40u64 {
        p.on_access(origin, 0x40, i, true, i);
    }
    p.on_retired(10);
    p.on_retired(20);
    assert!(
        !p.finetune_losses().is_empty(),
        "the native backend must report real fine-tune losses"
    );
    assert!(p.finetune_losses().iter().all(|l| l.is_finite()));
}
