# Single entry points shared by CI and humans (DESIGN.md §5).
#
#   make build         release build of the workspace
#   make test          tier-1 verify: cargo build --release && cargo test -q
#   make lint          rustfmt check + clippy -D warnings + check --all-targets
#   make check         cargo check --all-targets --release (benches/examples)
#   make eval-smoke    small parallel all-benchmark sweep → BENCH_eval.json
#   make inspect-smoke instrumented simulate + repro inspect → BENCH_telemetry.json
#   make trace-smoke   ingest ci/sample_trace.txt + sweep one trace cell
#   make oversub-smoke small oversubscription sweep → BENCH_oversub.json
#   make oversub-learned-smoke  learned-vs-lru eviction at severe
#                      pressure (ratio 0.25), full-run spmv cell
#   make serve-smoke   tiny multi-tenant serving run → BENCH_serve.json
#   make serve-smoke-fast  serve the trained native model on the fast
#                      kernel tier (runs model-smoke first)
#   make kernel-bench  GEMM kernel tiers at serving shapes → BENCH_gemm.json
#   make perf          simulator-throughput harness (repro perf): cargo
#                      benches + pinned hot-path matrix + end-to-end
#                      cells/sec → BENCH_sim.json, warn-only check vs
#                      ci/perf_baseline.json
#   make perf-smoke    short-window perf variant for PR CI
#   make train         train the native backend (streamtriad → artifacts/)
#   make train-transformer  train the Transformer reference backend
#   make analyze       transformer-vs-native attention analysis → BENCH_compare.json
#   make analyze-smoke tiny analyze run (CI) → BENCH_compare.json
#   make model-smoke   tiny train + native-backend eval pairs (CI)
#   make doc           cargo doc --no-deps with rustdoc warnings denied
#   make golden-check  CI metrics-regression gate vs ci/golden_metrics.json
#   make golden-update re-pin the goldens from a fresh run (commit the diff)
#   make eval          full paper-regime sweep (scale 4.0, 2M instructions)
#   make oversub       full oversubscription grid (ratios × evictions)
#   make artifacts     trace-gen + JAX AOT export (needs python + jax)

CARGO ?= cargo
PYTHON ?= python

.PHONY: build test lint fmt clippy check doc eval-smoke inspect-smoke trace-smoke oversub-smoke oversub-learned-smoke serve-smoke serve-smoke-fast kernel-bench perf perf-smoke train train-transformer analyze analyze-smoke model-smoke golden-check golden-update eval oversub artifacts clean

build:
	$(CARGO) build --release

# The repo's tier-1 verify (ROADMAP.md).
test:
	$(CARGO) build --release
	$(CARGO) test -q

fmt:
	$(CARGO) fmt --all --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

# Compile-gate benches (harness = false) and examples, which neither
# `cargo build` nor `cargo test` cover in release.
check:
	$(CARGO) check --all-targets --release

lint: fmt clippy check

# Rustdoc gate (CI `docs` job): broken intra-doc links and other
# rustdoc lints fail the build.
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

# Fast sweep for CI smoke: tiny scale + instruction cap, stride
# fallback (no PJRT artifacts needed). Produces BENCH_eval.json.
eval-smoke:
	$(CARGO) run --release --bin repro -- eval summary --no-pjrt \
		--scale 0.25 --max-instructions 200000 --out results-smoke

# Telemetry smoke (DESIGN.md §13): one instrumented oversubscribed
# simulate writes the span/rollup file, then `repro inspect` renders it
# and writes BENCH_telemetry.json — the inspect cross-checks (outcome
# reconciliation, hit-rate series integration) are the assertions.
inspect-smoke:
	$(CARGO) run --release --bin repro -- simulate --benchmark spmv \
		--prefetcher tree --oversubscribe 0.25 --scale 0.1 \
		--max-instructions 200000 \
		--telemetry results-smoke/telemetry.json
	$(CARGO) run --release --bin repro -- inspect \
		results-smoke/telemetry.json --out results-smoke

# Trace-ingestion smoke (CI): ingest the checked-in sample trace, list
# it, and sweep one `trace:` cell through the summary grid — the cells
# land in BENCH_eval.json tagged source=trace.
trace-smoke:
	$(CARGO) run --release --bin repro -- trace ingest ci/sample_trace.txt \
		--trace-dir results-smoke/traces
	$(CARGO) run --release --bin repro -- trace list \
		--trace-dir results-smoke/traces
	$(CARGO) run --release --bin repro -- eval summary --no-pjrt \
		--trace-dir results-smoke/traces --benchmarks trace:sample_trace \
		--scale 0.25 --max-instructions 200000 --out results-smoke

# Oversubscription smoke: 3 workloads, two ratios, full eviction axis.
# Produces BENCH_oversub.json.
oversub-smoke:
	$(CARGO) run --release --bin repro -- eval oversub --no-pjrt \
		--scale 0.25 --max-instructions 200000 --out results-smoke \
		--ratios 1.0,0.5 \
		--benchmarks addvectors --benchmarks atax --benchmarks pathfinder

# Learned-eviction smoke: the online-trained policy against lru at
# severe pressure (ratio 0.25) on one irregular workload, run to
# completion (--max-instructions 0) so the capped device genuinely
# fills — the cell the ISSUE's success metric reads.
oversub-learned-smoke:
	$(CARGO) run --release --bin repro -- eval oversub --no-pjrt \
		--scale 0.1 --max-instructions 0 --out results-smoke \
		--ratios 0.25 --evictions lru,learned --prefetchers dl \
		--benchmarks spmv

# Serving smoke (CI): two tenant streams through two router shards on
# the stride backend — exercises the sharded coordinator, the shared
# batcher, and the BENCH_serve.json telemetry path.
serve-smoke:
	$(CARGO) run --release --bin repro -- serve --backend stride \
		--streams 2 --shards 2 --max-faults 500 --scale 0.1 \
		--out results-smoke

# Precision-tier serving smoke (CI): serve the model model-smoke just
# trained on the fast (blocked f32) kernel tier. The golden gate and
# every training path stay on --precision exact; this exercises the
# quantized/fast serving plane end to end.
serve-smoke-fast: model-smoke
	$(CARGO) run --release --bin repro -- serve --backend native \
		--artifacts results-smoke/models --benchmark streamtriad \
		--precision fast \
		--streams 2 --shards 2 --max-faults 500 --scale 0.1 \
		--out results-smoke

# Kernel microbenches: every --precision tier (exact/fast/int8/int4) at
# the native model's serving GEMM shapes → BENCH_gemm.json at the repo
# root (schema bench_gemm/v1).
kernel-bench:
	$(CARGO) bench --bench gemm

# Simulator-throughput harness (DESIGN.md §12): the sim_core and
# prefetchers cargo benches plus `repro perf` all merge into one
# BENCH_sim.json (schema bench_sim/v1); the --check is warn-only with
# 2x tolerance against ci/perf_baseline.json (bootstrap baselines just
# print candidates — re-pin with `repro perf --check ... --update`).
perf:
	$(CARGO) bench --bench sim_core
	$(CARGO) bench --bench prefetchers
	$(CARGO) run --release --bin repro -- perf --check ci/perf_baseline.json

# Short-window variant for PR CI: skips the cargo benches, shrinks the
# measurement windows and the end-to-end cell set.
perf-smoke:
	$(CARGO) run --release --bin repro -- perf --smoke --check ci/perf_baseline.json

# Train the native (pure-Rust) predictor backend offline: access-stream
# harvest → vocab → windows → SGD/Adam → artifacts/<wl>.native.params.bin
# + vocab + manifest entry (arch=native). Add more workloads with
# `--benchmarks a --benchmarks b`.
train:
	$(CARGO) run --release --bin repro -- train --workload streamtriad --out artifacts

# Train the Transformer reference backend (the paper's unconstrained
# model — the accuracy ceiling) into the same artifacts manifest
# (arch=transformer); serve it with `--backend transformer`.
train-transformer:
	$(CARGO) run --release --bin repro -- train --arch transformer \
		--workload streamtriad --out artifacts

# Attention-interpretability analysis: train BOTH archs on the same
# corpus/seed, profile per-head attention entropy + slot locality over
# held-out windows, and write the transformer-vs-native cost table
# (top-1, params, FLOPs/inference, wall times, int4 quant error) as
# BENCH_compare.json (schema bench_compare/v1).
analyze:
	$(CARGO) run --release --bin repro -- analyze --workload streamtriad \
		--out results

# CI-sized analyze: tiny transformer, one workload, few steps.
analyze-smoke:
	$(CARGO) run --release --bin repro -- analyze --workload streamtriad \
		--out results-smoke --history-len 8 --epochs 2 --limit 20000 \
		--hidden 32 --d-model 16 --heads 2 --layers 1 --d-ff 32 \
		--max-maps 128 --scale 0.25 --max-instructions 200000

# CI model smoke: tiny offline train, then the U-vs-R pairs table served
# by the freshly trained native backend (offline-clean, no pjrt feature).
model-smoke:
	$(CARGO) run --release --bin repro -- train --workload streamtriad \
		--out results-smoke/models --history-len 8 --hidden 32 --epochs 2 \
		--limit 20000 --scale 0.25 --max-instructions 200000
	$(CARGO) run --release --bin repro -- eval pairs --backend native \
		--artifacts results-smoke/models \
		--scale 0.25 --max-instructions 200000 --out results-smoke

# Metrics-regression gate (CI): fixed 3-workload grid vs committed
# goldens, tolerances in the JSON. Update goldens deliberately with
# golden-update and commit the diff.
golden-check:
	$(CARGO) run --release --bin repro -- golden check --path ci/golden_metrics.json

golden-update:
	$(CARGO) run --release --bin repro -- golden update --path ci/golden_metrics.json

# Full paper-regime sweep (Tables 10/11 + headline summary).
eval:
	$(CARGO) run --release --bin repro -- eval all --no-pjrt

# Full oversubscription grid: {14 workloads — the dense suite plus the
# irregular bfs/spmv/hash_join trio} × {none,tree,uvmsmart,dl}
# × {1.0,0.75,0.5,0.375,0.25} × {lru,random,freq,prefetch-aware,learned}.
oversub:
	$(CARGO) run --release --bin repro -- eval oversub --no-pjrt

# Layer 2/1: train + AOT-export the predictor models from fresh traces.
artifacts:
	$(CARGO) run --release --bin repro -- trace-gen --out traces
	cd python && $(PYTHON) -m compile.aot --traces ../traces --out ../artifacts

clean:
	$(CARGO) clean
	rm -rf results results-smoke results-nightly traces \
		BENCH_eval.json BENCH_oversub.json BENCH_serve.json \
		BENCH_compare.json BENCH_gemm.json BENCH_sim.json \
		BENCH_telemetry.json
