# Single entry points shared by CI and humans (DESIGN.md §5).
#
#   make build       release build of the workspace
#   make test        tier-1 verify: cargo build --release && cargo test -q
#   make lint        rustfmt check + clippy with warnings denied
#   make eval-smoke  small parallel all-benchmark sweep → BENCH_eval.json
#   make eval        full paper-regime sweep (scale 4.0, 2M instructions)
#   make artifacts   trace-gen + JAX AOT export (needs python + jax)

CARGO ?= cargo
PYTHON ?= python

.PHONY: build test lint fmt clippy eval-smoke eval artifacts clean

build:
	$(CARGO) build --release

# The repo's tier-1 verify (ROADMAP.md).
test:
	$(CARGO) build --release
	$(CARGO) test -q

fmt:
	$(CARGO) fmt --all --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

lint: fmt clippy

# Fast sweep for CI smoke: tiny scale + instruction cap, stride
# fallback (no PJRT artifacts needed). Produces BENCH_eval.json.
eval-smoke:
	$(CARGO) run --release --bin repro -- eval summary --no-pjrt \
		--scale 0.25 --max-instructions 200000 --out results-smoke

# Full paper-regime sweep (Tables 10/11 + headline summary).
eval:
	$(CARGO) run --release --bin repro -- eval all --no-pjrt

# Layer 2/1: train + AOT-export the predictor models from fresh traces.
artifacts:
	$(CARGO) run --release --bin repro -- trace-gen --out traces
	cd python && $(PYTHON) -m compile.aot --traces ../traces --out ../artifacts

clean:
	$(CARGO) clean
	rm -rf results results-smoke traces BENCH_eval.json
