"""Data-pipeline tests: clustering, vocabulary, featurization,
sequence construction (paper §4/§5.1 semantics)."""

import numpy as np
import pytest

from compile import data as D
from tests.conftest import synth_trace


def test_build_vocab_finds_dominant_delta(strided_trace):
    v = D.build_vocab([strided_trace])
    assert v.dominant_delta == 2
    assert v.convergence > 0.95
    assert 2 in v.deltas
    assert v.n_classes == len(v.deltas) + 1


def test_vocab_encode_decode_roundtrip(strided_trace):
    v = D.build_vocab([strided_trace])
    for d in v.deltas:
        assert v.deltas[v.encode_delta(d)] == d
    assert v.encode_delta(987654321) == v.oov


def test_vocab_json_roundtrip(strided_trace, tmp_path):
    v = D.build_vocab([strided_trace])
    p = tmp_path / "v.json"
    v.save(str(p))
    import json
    v2 = D.Vocab.from_json(json.load(open(p)))
    assert v2.deltas == v.deltas
    assert v2.dominant_delta == v.dominant_delta
    assert abs(v2.convergence - v.convergence) < 1e-9


@pytest.mark.parametrize("cluster_by", D.CLUSTER_KEYS)
def test_cluster_ids_all_modes(strided_trace, cluster_by):
    ids = D.cluster_ids(strided_trace, cluster_by)
    assert len(ids) == len(strided_trace["page"])


def test_sm_warp_clusters_are_joint_key():
    t = synth_trace(n_clusters=4)
    ids = D.cluster_ids(t, "sm_warp")
    # 4 clusters built as (sm=c%2, warp=c//2) → 4 distinct joint keys.
    assert len(np.unique(ids)) == 4
    assert len(np.unique(D.cluster_ids(t, "sm"))) == 2


def test_dataset_shapes_and_labels(strided_trace):
    v = D.build_vocab([strided_trace])
    X, y = D.build_dataset(strided_trace, v, seq_len=10, distance=1, max_samples=1000)
    assert X.shape[1:] == (10, 3)
    assert X.dtype == np.int32
    assert len(X) == len(y)
    # A pure-stride trace: every label is the dominant delta's class.
    assert (y == v.encode_delta(2)).mean() > 0.99


def test_dataset_distance_shifts_labels():
    # Pattern with period-2 deltas (2, 4, 2, 4, ...): at distance 2 the
    # label equals the delta two steps ahead = same parity as current.
    rows = []
    page = 100
    for t in range(120):
        page += 2 if t % 2 == 0 else 4
        rows.append((t, 0x10, page, 0, 0, 0, 0, 0, 0, 1))
    arr = np.array(rows, dtype=np.int64)
    names = ("cycle", "pc", "page", "sm", "warp", "cta", "tpc", "kernel_id", "array_id", "miss")
    t = {k: arr[:, i] for i, k in enumerate(names)}
    v = D.build_vocab([t])
    X1, y1 = D.build_dataset(t, v, seq_len=4, distance=1, max_samples=10_000)
    X2, y2 = D.build_dataset(t, v, seq_len=4, distance=2, max_samples=10_000)
    # distance=2 labels are the distance=1 labels shifted by one step:
    # both alternate, but out of phase.
    assert set(np.unique(y1)) == set(np.unique(y2))
    assert len(X2) == len(X1) - 1


def test_dataset_respects_max_samples(strided_trace):
    v = D.build_vocab([strided_trace])
    X, y = D.build_dataset(strided_trace, v, seq_len=5, max_samples=37)
    assert len(X) <= 37


def test_featurize_all_13_features(strided_trace):
    v = D.build_vocab([strided_trace])
    X, y = D.build_dataset(strided_trace, v, features=D.ALL_FEATURES, seq_len=8)
    assert X.shape[2] == 13
    sizes = D.feature_vocab_sizes(v, D.ALL_FEATURES)
    assert len(sizes) == 13
    # Every token id must be within its declared vocab size.
    for f in range(13):
        assert X[:, :, f].min() >= 0
        assert X[:, :, f].max() < sizes[f], D.ALL_FEATURES[f]


def test_split_dataset_80_20(strided_trace):
    v = D.build_vocab([strided_trace])
    X, y = D.build_dataset(strided_trace, v, seq_len=6)
    (Xtr, ytr), (Xva, yva) = D.split_dataset(X, y)
    assert len(Xtr) == int(0.8 * len(X))
    assert len(Xtr) + len(Xva) == len(X)


def test_trace_too_small_raises():
    t = synth_trace(n_clusters=1, steps=5)
    v = D.build_vocab([t])
    with pytest.raises(ValueError):
        D.build_dataset(t, v, seq_len=30)


def test_max_classes_caps_vocab():
    t = synth_trace(noise_every=2, steps=400, seed=9)
    v = D.build_vocab([t], max_classes=8)
    assert len(v.deltas) == 8
    assert v.n_classes == 9
