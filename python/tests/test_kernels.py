"""Layer-1 kernel tests: the Pallas HLSH attention against the pure-jnp
oracle, with hypothesis sweeping shapes and value ranges (the L1
correctness gate of the three-layer stack)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels.hlsh import hlsh_attention
from compile.kernels.ref import (
    full_attention_ref,
    hlsh_attention_batched_ref,
    hlsh_masks,
    hscore,
    lsh_hash,
)

jax.config.update("jax_platform_name", "cpu")


def make_inputs(b, s, d, h, seed=0, scale=1.0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    qk = jax.random.normal(k1, (b, s, d), dtype=jnp.float32) * scale
    v = jax.random.normal(k2, (b, s, d), dtype=jnp.float32) * scale
    hashes = lsh_hash(qk, h)
    return qk, v, hashes


# -------------------------------------------------------------------------
# hypothesis sweep: kernel == oracle over shapes/values
# -------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    s=st.sampled_from([4, 8, 30, 32]),
    d=st.sampled_from([4, 8, 12, 16]),
    h=st.sampled_from([8, 16]),
    seed=st.integers(0, 10_000),
    scale=st.sampled_from([0.1, 1.0, 8.0]),
)
def test_hlsh_kernel_matches_ref(b, s, d, h, seed, scale):
    qk, v, hashes = make_inputs(b, s, d, h, seed, scale)
    htop, hbot = 0.9 * h, 0.1 * h
    out_k = hlsh_attention(qk, v, hashes, htop, hbot)
    out_r = hlsh_attention_batched_ref(qk, v, hashes, htop, hbot)
    # f32 matmul/softmax accumulate in different orders in the
    # interpret-mode kernel vs the vmapped reference — allow a few ulp.
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-4, atol=1e-4)


def test_kernel_output_shape_and_dtype():
    qk, v, hashes = make_inputs(3, 30, 12, 16)
    out = hlsh_attention(qk, v, hashes, 14.4, 1.6)
    assert out.shape == (3, 30, 12)
    assert out.dtype == jnp.float32
    assert bool(jnp.isfinite(out).all())


# -------------------------------------------------------------------------
# algorithmic properties (Algorithm 1 semantics)
# -------------------------------------------------------------------------

def test_lsh_hash_is_deterministic_and_binary():
    qk, _, _ = make_inputs(2, 8, 12, 16)
    h1 = lsh_hash(qk, 16)
    h2 = lsh_hash(qk, 16)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    assert set(np.unique(np.asarray(h1))).issubset({0, 1})


def test_lsh_similar_vectors_get_similar_codes():
    base = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 12))
    near = base + 1e-4
    far = -base
    codes = lsh_hash(jnp.concatenate([base, near, far], axis=1), 32)[0]
    ham_near = int((codes[0] != codes[1]).sum())
    ham_far = int((codes[0] != codes[2]).sum())
    assert ham_near == 0
    assert ham_far == 32, "antipodal vector flips every angular bit"


def test_hscore_zero_for_identical_rows():
    hashes = jnp.zeros((8, 16), dtype=jnp.int32)
    s = np.asarray(hscore(hashes))
    assert (s < 0.01).all(), "identical codes → geomean distance ~0"


def test_masks_share_groups_identical_rows():
    # All rows identical → everything is 'share': base row kept, rest
    # erased and copy-marked.
    hashes = jnp.ones((6, 16), dtype=jnp.int32)
    keep, base_idx, share_rest = hlsh_masks(hashes, htop=14.4, hbot=1.6)
    assert int(base_idx) == 0
    assert np.asarray(share_rest)[1:].all()
    assert not bool(np.asarray(share_rest)[0])
    assert np.asarray(keep)[1:].sum() == 0


def test_shared_rows_copy_base_output():
    # Identical qk rows → identical hash codes → share group; the
    # kernel must emit identical outputs for all shared rows.
    qk = jnp.tile(jax.random.normal(jax.random.PRNGKey(1), (1, 1, 12)), (1, 8, 1))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 12))
    hashes = lsh_hash(qk, 16)
    out = np.asarray(hlsh_attention(qk, v, hashes, 14.4, 1.6))
    for i in range(1, 8):
        np.testing.assert_allclose(out[0, i], out[0, 0], rtol=1e-6)


def test_erase_rows_with_distant_codes():
    # One row antipodal to all others: its Hamming distance is maximal
    # → HSCORE ≥ HTOP → erased from the attention matrix.
    base = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 12))
    rows = jnp.tile(base, (1, 7, 1))
    outlier = -base * 5
    qk = jnp.concatenate([rows, outlier], axis=1)
    hashes = lsh_hash(qk, 16)
    keep, _, _ = hlsh_masks(hashes[0], htop=14.4, hbot=1.6)
    assert np.asarray(keep)[-1] == 0.0, "outlier erased"


def test_full_attention_ref_is_softmax_weighted():
    qk, v, _ = make_inputs(2, 6, 4, 8)
    out = full_attention_ref(qk, v)
    assert out.shape == v.shape
    # Rows of the attention matrix sum to 1 → output within convex
    # hull of V values along each dim.
    lo = np.asarray(v).min(axis=1, keepdims=True) - 1e-5
    hi = np.asarray(v).max(axis=1, keepdims=True) + 1e-5
    o = np.asarray(out)
    assert (o >= lo).all() and (o <= hi).all()


# -------------------------------------------------------------------------
# autodiff path (the custom_vjp used by training)
# -------------------------------------------------------------------------

def test_hlsh_gradients_match_reference():
    qk, v, hashes = make_inputs(2, 8, 12, 16, seed=5)
    htop, hbot = 14.4, 1.6

    def loss_kernel(q, v_):
        return hlsh_attention(q, v_, hashes, htop, hbot).sum()

    def loss_ref(q, v_):
        return hlsh_attention_batched_ref(q, v_, hashes, htop, hbot).sum()

    gk = jax.grad(loss_kernel, argnums=(0, 1))(qk, v)
    gr = jax.grad(loss_ref, argnums=(0, 1))(qk, v)
    np.testing.assert_allclose(np.asarray(gk[0]), np.asarray(gr[0]), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gk[1]), np.asarray(gr[1]), rtol=1e-4, atol=1e-5)


def test_hlsh_jits_and_lowers():
    # The kernel must lower inside jit (the AOT path requirement).
    qk, v, hashes = make_inputs(2, 30, 12, 16)

    @jax.jit
    def f(q, v_, h_):
        return hlsh_attention(q, v_, h_, 14.4, 1.6)

    out = f(qk, v, hashes)
    assert out.shape == (2, 30, 12)
