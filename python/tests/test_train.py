"""Training-loop + metric tests: the revised predictor must learn a
synthetic strided trace to high accuracy (the pipeline-level smoke of
Table 1), and the metric implementations must match hand-computed
values."""

import numpy as np
import pytest

from compile import data as D
from compile.model import make_fc, make_revised
from compile.train import metrics_from_logits, train, weighted_f1
from tests.conftest import synth_trace


def test_weighted_f1_hand_example():
    y_true = np.array([0, 0, 0, 1, 1, 2])
    y_pred = np.array([0, 0, 1, 1, 1, 0])
    # class 0: tp=2 fp=1 fn=1 → p=2/3 r=2/3 f1=2/3 (support 3)
    # class 1: tp=2 fp=1 fn=0 → p=2/3 r=1   f1=0.8 (support 2)
    # class 2: tp=0 → f1=0 (support 1)
    expected = (3 * (2 / 3) + 2 * 0.8 + 0) / 6
    assert abs(weighted_f1(y_true, y_pred) - expected) < 1e-9


def test_weighted_f1_perfect_prediction():
    y = np.array([3, 1, 4, 1, 5])
    assert weighted_f1(y, y) == 1.0


def test_metrics_from_logits_topk():
    logits = np.array([
        [0.1, 0.9, 0.0, 0.0],
        [0.9, 0.1, 0.0, 0.0],
        [0.0, 0.0, 0.1, 0.9],
    ])
    y = np.array([1, 1, 2])
    m = metrics_from_logits(logits, y)
    # Row 0 argmax=1 ✓, row 1 argmax=0 ✗, row 2 argmax=3 ✗.
    assert abs(m["top1"] - 1 / 3) < 1e-9
    assert m["top10"] == 1.0, "4 classes < 10 → top-10 is always 1 unless class missing"


def test_revised_learns_strided_trace():
    t = synth_trace(n_clusters=4, steps=400, stride=2)
    v = D.build_vocab([t])
    X, y = D.build_dataset(t, v, seq_len=8, max_samples=5000)
    (Xtr, ytr), (Xva, yva) = D.split_dataset(X, y)
    sizes = D.feature_vocab_sizes(v)
    init, apply = make_revised(sizes, v.n_classes, seq_len=8)
    res = train(init, apply, Xtr, ytr, epochs=3, batch_size=64, eval_data=(Xva, yva), clamp=True)
    assert res.top1 > 0.95, f"top1 {res.top1}"
    assert res.losses[-1] < res.losses[0]


def test_fc_learns_periodic_pattern():
    # Dominant-delta pattern — solvable without attention (Table 4's
    # point for the ATAX/BICG/MVT degenerate cases).
    rows = []
    page = 0
    for t in range(600):
        page += 4 if t % 20 else 9  # 95% dominant delta
        rows.append((t, 0x10, page, 0, 0, 0, 0, 0, 0, 1))
    arr = np.array(rows, dtype=np.int64)
    names = ("cycle", "pc", "page", "sm", "warp", "cta", "tpc", "kernel_id", "array_id", "miss")
    trace = {k: arr[:, i] for i, k in enumerate(names)}
    v = D.build_vocab([trace])
    X, y = D.build_dataset(trace, v, seq_len=6, max_samples=4000)
    sizes = D.feature_vocab_sizes(v)
    init, apply = make_fc(sizes, v.n_classes, seq_len=6)
    res = train(init, apply, X, y, epochs=5, batch_size=64)
    assert res.top1 > 0.9, f"top1 {res.top1}"


def test_clamped_training_keeps_weights_in_range():
    t = synth_trace(steps=120)
    v = D.build_vocab([t])
    X, y = D.build_dataset(t, v, seq_len=6, max_samples=500)
    sizes = D.feature_vocab_sizes(v)
    init, apply = make_revised(sizes, v.n_classes, seq_len=6)
    res = train(init, apply, X, y, epochs=1, batch_size=32, clamp=True)
    import jax

    for leaf in jax.tree_util.tree_leaves(res.params):
        assert float(abs(leaf).max()) <= 8.0
