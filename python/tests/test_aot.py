"""AOT-bridge tests: the tensor-store format (bit-parity with the Rust
reader), HLO text emission, manifest schema, and numerical parity of
the lowered inference function."""

import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import data as D
from compile.model import make_revised
from tests.conftest import synth_trace

SIZES = [16, 64, 10]


def small_model(seed=0, seq_len=8):
    init, apply = make_revised(SIZES, 11, seq_len=seq_len)
    return init(jax.random.PRNGKey(seed)), apply


def test_save_params_binary_layout(tmp_path):
    p = tmp_path / "t.bin"
    aot.save_params(str(p), [("w", np.array([1.0, -2.5], np.float32))])
    raw = p.read_bytes()
    assert raw[:4] == b"UVMT"
    version, count = struct.unpack("<II", raw[4:12])
    assert (version, count) == (1, 1)
    name_len = struct.unpack("<H", raw[12:14])[0]
    assert raw[14:15] == b"w" and name_len == 1
    dtype, ndim = raw[15], raw[16]
    assert (dtype, ndim) == (0, 1)
    dim0 = struct.unpack("<I", raw[17:21])[0]
    assert dim0 == 2
    nbytes = struct.unpack("<Q", raw[21:29])[0]
    assert nbytes == 8
    vals = struct.unpack("<ff", raw[29:37])
    assert vals == (1.0, -2.5)


def test_quant_pack_matches_rust_scheme():
    # Mirrors rust predictor/quant.rs: step = 16/15, low nibble first.
    vals = np.array([-8.0, 8.0, 0.0], np.float32)
    packed = aot.quant_pack(vals)
    assert len(packed) == 2
    assert packed[0] & 0x0F == 0        # -8 → code 0
    assert (packed[0] >> 4) == 15       # +8 → code 15
    mid = packed[1] & 0x0F              # 0.0 → nearest code to 7.5
    assert mid in (7, 8)


def test_flatten_params_order_is_sorted():
    params, _ = small_model()
    names, arrays, _ = aot.flatten_params(params)
    assert names == sorted(names)
    assert len(names) == len(arrays)


def test_lower_infer_emits_hlo_text():
    params, apply = small_model()
    hlo = aot.lower_infer(apply, params, batch=4, seq_len=8, n_feat=3)
    assert "ENTRY" in hlo and "HloModule" in hlo
    # One parameter per tensor + the token input.
    n_params = len(aot.flatten_params(params)[0])
    assert hlo.count("parameter(") >= n_params + 1


def test_lower_train_emits_hlo_text():
    params, apply = small_model()
    hlo = aot.lower_train(apply, params, batch=4, seq_len=8, n_feat=3)
    assert "ENTRY" in hlo
    # SGD step must reference all parameters and produce a tuple root.
    assert "tuple(" in hlo or "tuple " in hlo


def test_export_model_writes_complete_artifact_set(tmp_path):
    t = synth_trace(steps=120)
    vocab = D.build_vocab([t], history_len=8)
    sizes = D.feature_vocab_sizes(vocab)
    init, apply = make_revised(sizes, vocab.n_classes, seq_len=8)
    params = init(jax.random.PRNGKey(1))
    entry = aot.export_model(str(tmp_path), "demo", vocab, params, apply, seq_len=8)
    for key in ("infer_hlo", "train_hlo", "params", "vocab"):
        assert (tmp_path / entry[key]).exists(), key
    assert entry["n_classes"] == vocab.n_classes
    assert entry["n_features"] == 3
    v = json.load(open(tmp_path / entry["vocab"]))
    assert v["history_len"] == 8
    assert entry["n_params"] == len(aot.flatten_params(params)[0])


def test_lowered_infer_matches_eager():
    """The HLO function computes exactly what apply() computes — the
    numerical contract the Rust runtime depends on."""
    from jax._src.lib import xla_client as xc

    params, apply = small_model(seed=2)
    names, arrays, treedef = aot.flatten_params(params)
    rng = np.random.default_rng(0)
    tokens = np.stack(
        [rng.integers(0, v, size=(4, 8)) for v in SIZES], axis=-1
    ).astype(np.int32)

    hlo = aot.lower_infer(apply, params, batch=4, seq_len=8, n_feat=3)
    # Execute the HLO text through the same client family rust uses.
    client = xc.make_cpu_client()
    # Round-trip text→computation is covered on the rust side; here we
    # check eager-vs-jit on the same lowering path instead.
    def fn(*args):
        flat, toks = args[:-1], args[-1]
        p = jax.tree_util.tree_unflatten(treedef, list(flat))
        return (apply(p, toks),)

    jit_out = jax.jit(fn)(*[jnp.asarray(a) for a in arrays], jnp.asarray(tokens))[0]
    eager_out = apply(params, jnp.asarray(tokens))
    np.testing.assert_allclose(np.asarray(jit_out), np.asarray(eager_out), rtol=1e-5, atol=1e-5)
    assert len(hlo) > 100


def test_train_step_lowering_reduces_loss_numerically():
    """Apply the lowered train-step math (via jit) twice and verify the
    loss drops — the online fine-tune contract."""
    from compile import nn

    params, apply = small_model(seed=3)
    names, arrays, treedef = aot.flatten_params(params)
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(np.stack(
        [rng.integers(0, v, size=(16, 8)) for v in SIZES], axis=-1
    ).astype(np.int32))
    labels = jnp.asarray((np.arange(16) % 11).astype(np.int32))

    def step(*args):
        flat, toks, labs = args[:-2], args[-2], args[-1]
        p = jax.tree_util.tree_unflatten(treedef, list(flat))

        def loss_fn(p_):
            return nn.cross_entropy(apply(p_, toks), labs)

        loss, grads = jax.value_and_grad(loss_fn)(p)
        p2 = nn.clip_params(nn.sgd_step(p, grads, lr=0.05))
        flat2, _ = jax.tree_util.tree_flatten(p2)
        return tuple(flat2) + (loss,)

    jit_step = jax.jit(step)
    flat = [jnp.asarray(a) for a in arrays]
    out1 = jit_step(*flat, tokens, labels)
    loss1 = float(out1[-1])
    out2 = jit_step(*out1[:-1], tokens, labels)
    loss2 = float(out2[-1])
    assert loss2 < loss1, f"{loss2} !< {loss1}"
