"""Shared fixtures: synthetic traces shaped like `repro trace-gen`
output (so the python pipeline is testable without the Rust binary)."""

import pathlib
import sys

# The test modules import the `compile` package; make the suite
# runnable from the repo root (CI: `python -m pytest python/tests`)
# as well as from `python/`.
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import numpy as np
import pytest


def synth_trace(n_clusters=4, steps=200, stride=2, pc_cycle=(0x1000, 0x1008, 0x1010),
                noise_every=0, seed=0):
    """A trace dict with per-(sm,warp) strided page streams."""
    rng = np.random.default_rng(seed)
    rows = []
    cycle = 0
    for c in range(n_clusters):
        page = 1000 * (c + 1)
        for t in range(steps):
            pc = pc_cycle[t % len(pc_cycle)]
            if noise_every and t % noise_every == noise_every - 1:
                page += int(rng.integers(3, 60))
            else:
                page += stride
            rows.append((cycle, pc, page, c % 2, c // 2, c, (c % 2) // 2, 0, 0, 1))
            cycle += 3
    rows.sort(key=lambda r: r[0])
    arr = np.array(rows, dtype=np.int64)
    names = ("cycle", "pc", "page", "sm", "warp", "cta", "tpc", "kernel_id", "array_id", "miss")
    return {k: arr[:, i] for i, k in enumerate(names)}


@pytest.fixture
def strided_trace():
    return synth_trace()


@pytest.fixture
def noisy_trace():
    return synth_trace(noise_every=7, seed=3)
