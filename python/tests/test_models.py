"""Layer-2 model-zoo tests: shapes, determinism, trainability, and the
quantization clamp."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import nn
from compile.model import MODEL_FACTORIES, make_model, make_revised

SIZES3 = [16, 64, 10]  # pc, page, delta vocab sizes
SIZES13 = [16, 2, 64, 64, 32, 256, 64, 64, 64, 16, 10, 128, 16]


def toy_tokens(b=4, s=12, sizes=SIZES3, seed=0):
    rng = np.random.default_rng(seed)
    toks = np.stack(
        [rng.integers(0, v, size=(b, s)) for v in sizes], axis=-1
    ).astype(np.int32)
    return jnp.asarray(toks)


@pytest.mark.parametrize("arch", sorted(MODEL_FACTORIES))
def test_every_arch_produces_logits(arch):
    sizes = SIZES13 if arch == "transformer" else SIZES3
    n_classes = 10
    init, apply = make_model(arch, sizes, n_classes, seq_len=12)
    params = init(jax.random.PRNGKey(0))
    logits = apply(params, toy_tokens(sizes=sizes))
    assert logits.shape == (4, n_classes)
    assert bool(jnp.isfinite(logits).all()), arch


@pytest.mark.parametrize("arch", sorted(MODEL_FACTORIES))
def test_every_arch_is_deterministic(arch):
    sizes = SIZES13 if arch == "transformer" else SIZES3
    init, apply = make_model(arch, sizes, 10, seq_len=12)
    params = init(jax.random.PRNGKey(1))
    t = toy_tokens(sizes=sizes)
    a = apply(params, t)
    b = apply(params, t)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_revised_attention_variants_differ():
    init, apply_hlsh = make_revised(SIZES3, 10, seq_len=12, attention="hlsh")
    _, apply_full = make_revised(SIZES3, 10, seq_len=12, attention="full")
    _, apply_none = make_revised(SIZES3, 10, seq_len=12, attention="none")
    params = init(jax.random.PRNGKey(2))
    t = toy_tokens()
    out_h = np.asarray(apply_hlsh(params, t))
    out_f = np.asarray(apply_full(params, t))
    out_n = np.asarray(apply_none(params, t))
    # Attention-off is structurally different; hlsh approximates full.
    assert not np.allclose(out_h, out_n)
    # HLSH should land closer to full attention than attention-off does.
    assert np.abs(out_h - out_f).mean() <= np.abs(out_n - out_f).mean() + 1e-3


def test_revised_pallas_and_ref_paths_agree():
    init, apply_pl = make_revised(SIZES3, 10, seq_len=12, use_pallas=True)
    _, apply_ref = make_revised(SIZES3, 10, seq_len=12, use_pallas=False)
    params = init(jax.random.PRNGKey(3))
    t = toy_tokens()
    np.testing.assert_allclose(
        np.asarray(apply_pl(params, t)), np.asarray(apply_ref(params, t)),
        rtol=2e-5, atol=2e-5,
    )


def test_quant_clamp_bounds_params_after_step():
    init, apply = make_revised(SIZES3, 10, seq_len=12)
    params = init(jax.random.PRNGKey(4))
    # Blow a weight out of range, then verify clip_params restores it.
    params["head_w"] = params["head_w"] + 100.0
    clipped = nn.clip_params(params)
    for v in jax.tree_util.tree_leaves(clipped):
        assert float(jnp.max(jnp.abs(v))) <= 8.0


def test_gradient_step_reduces_loss():
    init, apply = make_revised(SIZES3, 10, seq_len=12)
    params = init(jax.random.PRNGKey(5))
    t = toy_tokens(b=32)
    labels = jnp.asarray(np.arange(32) % 10, dtype=jnp.int32)

    def loss(p):
        return nn.cross_entropy(apply(p, t), labels)

    l0 = float(loss(params))
    opt = nn.adam_init(params)
    for _ in range(20):
        g = jax.grad(loss)(params)
        params, opt = nn.adam_step(params, opt, g, lr=5e-3)
    assert float(loss(params)) < l0 * 0.9


def test_positional_encoding_properties():
    pe = nn.positional_encoding(30, 12)
    assert pe.shape == (30, 12)
    # Even dims are sin (0 at pos 0), odd dims cos (1 at pos 0).
    np.testing.assert_allclose(np.asarray(pe[0, 0::2]), 0.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(pe[0, 1::2]), 1.0, atol=1e-6)


def test_transformer_shuffle_sensitivity_machinery():
    """Shuffling token order changes the transformer's output (the
    Fig. 6 experiment machinery is meaningful)."""
    init, apply = make_model("transformer", SIZES13, 10, seq_len=12)
    params = init(jax.random.PRNGKey(6))
    t = toy_tokens(sizes=SIZES13, seed=7)
    shuffled = t[:, ::-1, :]
    a = np.asarray(apply(params, t))
    b = np.asarray(apply(params, shuffled))
    assert not np.allclose(a, b), "positional encoding must break permutation invariance"


def test_lstm_final_state_depends_on_order():
    init, apply = make_model("lstm", SIZES3, 10, seq_len=12)
    params = init(jax.random.PRNGKey(8))
    t = toy_tokens(seed=9)
    a = np.asarray(apply(params, t))
    b = np.asarray(apply(params, t[:, ::-1, :]))
    assert not np.allclose(a, b)
