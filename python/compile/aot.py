"""AOT compilation: train the predictors and emit the Rust-consumable
artifacts.

    python -m compile.aot --traces ../traces --out ../artifacts

Outputs, per model (9 per-benchmark revised models + the "shared"
model pre-trained on the paper's 5-benchmark corpus, §7.1):

    <name>.infer.hlo.txt   logits = f(p_0..p_k, tokens i32[B,S,3])
    <name>.train.hlo.txt   (p_0'..p_k', loss) = g(p.., tokens, labels)
    <name>.params.bin      tensor store (f32; int4 path covered by tests)
    <name>.vocab.json      delta vocabulary + encoders
    manifest.json          registry (rust runtime entry point)

HLO **text** is the interchange format — the image's xla_extension
0.5.1 rejects jax≥0.5 serialized protos (64-bit instruction ids); the
text parser reassigns ids (see /opt/xla-example/README.md).

Parameter convention: the model's param dict flattens in sorted-key
order (jax dict flattening); the executables take those tensors as
leading positional arguments so the Rust runtime can keep them
device-resident and swap them after fine-tune steps.
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from . import nn
from .model import make_revised
from .train import train

MAGIC = b"UVMT"
DT_F32, DT_I32, DT_I4 = 0, 1, 2

# Quantization constants — must match rust predictor/quant.rs.
QUANT_LO, QUANT_HI, QUANT_LEVELS = -8.0, 8.0, 16
QUANT_STEP = (QUANT_HI - QUANT_LO) / (QUANT_LEVELS - 1)

# The paper's pretraining corpus (§7.1): "we randomly select 5
# benchmark applications (ATAX, Backprop, Bicg, Hotspot, NW)".
SHARED_CORPUS = ("atax", "backprop", "bicg", "hotspot", "nw")

INFER_BATCH = 8
TRAIN_BATCH = 16
FINETUNE_LR = 0.05


# ---------------------------------------------------------------------------
# tensor store (shared format with rust runtime/params.rs)
# ---------------------------------------------------------------------------

def quant_pack(values: np.ndarray) -> bytes:
    codes = np.clip(np.round((np.clip(values, QUANT_LO, QUANT_HI) - QUANT_LO) / QUANT_STEP),
                    0, QUANT_LEVELS - 1).astype(np.uint8).reshape(-1)
    if len(codes) % 2:
        codes = np.concatenate([codes, np.zeros(1, np.uint8)])
    packed = (codes[0::2] | (codes[1::2] << 4)).astype(np.uint8)
    return packed.tobytes()


def save_params(path: str, named_tensors, dtype=DT_F32):
    """Write the UVMT tensor store (see rust runtime/params.rs)."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", 1))
        f.write(struct.pack("<I", len(named_tensors)))
        for name, arr in named_tensors:
            arr = np.asarray(arr, dtype=np.float32)
            name_b = name.encode()
            f.write(struct.pack("<H", len(name_b)))
            f.write(name_b)
            f.write(struct.pack("<BB", dtype, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            if dtype == DT_F32:
                raw = arr.astype("<f4").tobytes()
            elif dtype == DT_I4:
                raw = quant_pack(arr)
            else:
                raise ValueError(dtype)
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


def flatten_params(params: dict):
    """Flatten to (names, arrays) in the canonical (sorted-key) order."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    names = sorted(params.keys())
    assert len(names) == len(leaves), "params must be a flat dict"
    return names, [np.asarray(l) for l in leaves], treedef


# ---------------------------------------------------------------------------
# HLO lowering
# ---------------------------------------------------------------------------

def to_hlo_text(lowered, return_tuple: bool = True) -> str:
    """Lower to HLO text. `return_tuple=True` for single-output infer
    (the Rust side unwraps a 1-tuple); the train step uses
    `return_tuple=False` so PJRT returns one buffer per output — the
    updated parameters stay device-resident and the xla crate's
    tuple-literal decomposition (which is not memory-safe for wide
    tuples) is never exercised."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def lower_infer(apply_fn, params: dict, batch: int, seq_len: int, n_feat: int) -> str:
    names, arrays, treedef = flatten_params(params)

    def fn(*args):
        flat, tokens = args[:-1], args[-1]
        p = jax.tree_util.tree_unflatten(treedef, list(flat))
        return (apply_fn(p, tokens),)

    specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in arrays]
    tok_spec = jax.ShapeDtypeStruct((batch, seq_len, n_feat), jnp.int32)
    lowered = jax.jit(fn).lower(*specs, tok_spec)
    return to_hlo_text(lowered)


def lower_train(apply_fn, params: dict, batch: int, seq_len: int, n_feat: int,
                lr: float = FINETUNE_LR) -> str:
    """One SGD step: (params…, tokens, labels) → (flat_params', loss).

    The updated parameters come back as ONE concatenated f32 vector
    (the Rust runtime splits it by the tensor-store shapes): the xla
    crate's literal tuple decomposition is only exercised for a
    2-tuple, the same code path the infer module's 1-tuple uses.
    """
    names, arrays, treedef = flatten_params(params)

    def fn(*args):
        flat, tokens, labels = args[:-2], args[-2], args[-1]
        p = jax.tree_util.tree_unflatten(treedef, list(flat))

        def loss_fn(p_):
            return nn.cross_entropy(apply_fn(p_, tokens), labels)

        loss, grads = jax.value_and_grad(loss_fn)(p)
        p2 = nn.clip_params(nn.sgd_step(p, grads, lr=lr))
        flat2, _ = jax.tree_util.tree_flatten(p2)
        packed = jnp.concatenate([jnp.ravel(x) for x in flat2])
        return (packed, loss)

    specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in arrays]
    tok_spec = jax.ShapeDtypeStruct((batch, seq_len, n_feat), jnp.int32)
    lab_spec = jax.ShapeDtypeStruct((batch,), jnp.int32)
    lowered = jax.jit(fn).lower(*specs, tok_spec, lab_spec)
    return to_hlo_text(lowered, return_tuple=True)


# ---------------------------------------------------------------------------
# model building + export
# ---------------------------------------------------------------------------

def train_revised_for(traces: list, *, seq_len: int, epochs: int,
                      max_samples: int, log, seed=0):
    """Build vocab + dataset from one or more traces, train the revised
    predictor (clamped), return (vocab, params, apply_fn, metrics)."""
    vocab = D.build_vocab(traces, history_len=seq_len)
    sizes = D.feature_vocab_sizes(vocab, D.REVISED_FEATURES)

    Xs, ys = [], []
    for t in traces:
        X, y = D.build_dataset(t, vocab, features=D.REVISED_FEATURES,
                               seq_len=seq_len, max_samples=max_samples // len(traces))
        Xs.append(X)
        ys.append(y)
    X, y = np.concatenate(Xs), np.concatenate(ys)
    (Xtr, ytr), (Xva, yva) = D.split_dataset(X, y)

    init_fn, apply_fn = make_revised(sizes, vocab.n_classes, seq_len=seq_len)
    # Small traces (stencil benchmarks at low fault volume) would get
    # almost no optimizer steps at the default batch of 256 — shrink
    # the batch so every model sees ≥ ~40 steps/epoch.
    batch = int(min(256, max(16, len(Xtr) // 40)))
    res = train(init_fn, apply_fn, Xtr, ytr, epochs=epochs, batch_size=batch,
                clamp=True, eval_data=(Xva, yva), seed=seed, log=log)
    return vocab, res, apply_fn


def export_model(out_dir: str, name: str, vocab, params, apply_fn,
                 seq_len: int, with_train: bool = True) -> dict:
    """Write all artifacts for one model; returns its manifest entry."""
    n_feat = len(D.REVISED_FEATURES)
    infer_hlo = f"{name}.infer.hlo.txt"
    with open(os.path.join(out_dir, infer_hlo), "w") as f:
        f.write(lower_infer(apply_fn, params, INFER_BATCH, seq_len, n_feat))
    train_hlo = None
    if with_train:
        train_hlo = f"{name}.train.hlo.txt"
        with open(os.path.join(out_dir, train_hlo), "w") as f:
            f.write(lower_train(apply_fn, params, TRAIN_BATCH, seq_len, n_feat))

    names, arrays, _ = flatten_params(params)
    save_params(os.path.join(out_dir, f"{name}.params.bin"),
                list(zip(names, arrays)), dtype=DT_F32)
    vocab.save(os.path.join(out_dir, f"{name}.vocab.json"))

    entry = {
        "infer_hlo": infer_hlo,
        "params": f"{name}.params.bin",
        "vocab": f"{name}.vocab.json",
        "batch": INFER_BATCH,
        "train_batch": TRAIN_BATCH,
        "seq_len": seq_len,
        "n_features": n_feat,
        "n_classes": vocab.n_classes,
        "n_params": len(names),
        "arch": "revised",
    }
    if train_hlo:
        entry["train_hlo"] = train_hlo
    return entry


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--traces", default="../traces")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--benchmarks", nargs="*", default=None,
                    help="default: traces/benchmarks.json model list")
    ap.add_argument("--seq-len", type=int, default=30)
    ap.add_argument("--epochs", type=int, default=int(os.environ.get("AOT_EPOCHS", "4")))
    ap.add_argument("--max-samples", type=int, default=int(os.environ.get("AOT_SAMPLES", "60000")))
    ap.add_argument("--trace-limit", type=int, default=300_000)
    ap.add_argument("--skip-shared", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()

    if args.benchmarks:
        benchmarks = args.benchmarks
    else:
        with open(os.path.join(args.traces, "benchmarks.json")) as f:
            benchmarks = json.load(f)["model"]

    def log(msg):
        print(f"[aot +{time.time() - t0:6.1f}s] {msg}", flush=True)

    models = {}
    traces_cache = {}

    def load(b):
        if b not in traces_cache:
            traces_cache[b] = D.load_trace(D.trace_path(args.traces, b), args.trace_limit)
        return traces_cache[b]

    # Per-benchmark revised models.
    for b in benchmarks:
        log(f"training revised model for {b}…")
        vocab, res, apply_fn = train_revised_for(
            [load(b)], seq_len=args.seq_len, epochs=args.epochs,
            max_samples=args.max_samples, log=log)
        log(f"  {b}: f1={res.f1:.4f} top1={res.top1:.4f} top10={res.top10:.4f} "
            f"classes={vocab.n_classes} conv={vocab.convergence:.3f}")
        models[b] = export_model(args.out, b, vocab, res.params, apply_fn, args.seq_len)

    # Shared pretrained model (paper §7.1's 5-benchmark corpus).
    if not args.skip_shared:
        corpus = [b for b in SHARED_CORPUS if b in benchmarks or
                  os.path.exists(D.trace_path(args.traces, b))]
        log(f"training shared model on {corpus}…")
        vocab, res, apply_fn = train_revised_for(
            [load(b) for b in corpus], seq_len=args.seq_len,
            epochs=args.epochs, max_samples=args.max_samples, log=log)
        log(f"  shared: f1={res.f1:.4f} top1={res.top1:.4f}")
        models["shared"] = export_model(args.out, "shared", vocab, res.params,
                                        apply_fn, args.seq_len)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump({"version": 1, "models": models}, f, indent=1)
    log(f"wrote {len(models)} models to {args.out}/manifest.json")


if __name__ == "__main__":
    main()
