"""Layer 1 — the HLSH attention mechanism (paper Algorithm 1) as a
Pallas kernel.

The kernel fuses, per batch element:
  Hamming scoring of the LSH codes → erase/share masking → masked
  shared-QK attention → shared-row output copy.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's
implementation targets CUDA; on a TPU-shaped target the whole
(S=30, D=12) working set fits one VMEM-resident block, so the grid
iterates over the batch only and every phase is expressed as dense
masked arithmetic (multiplicative masks instead of gather/scatter —
the MXU wants dense tiles and the zeroed rows are free relative to
re-tiling). `interpret=True` everywhere: the CPU PJRT client cannot
execute Mosaic custom-calls, and numerics are identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EPS


def _hlsh_kernel(qk_ref, v_ref, hashes_ref, o_ref, *, htop: float, hbot: float):
    """One batch element: blocks are [1, S, D] / [1, S, H]; index away
    the unit batch dim."""
    qk = qk_ref[0]
    v = v_ref[0]
    hashes = hashes_ref[0]
    s_len, d = qk.shape

    # --- Hamming scoring (Algorithm 1 lines 2-3) -----------------------
    sampled = hashes[::2]  # deterministic seq/2 sample
    diff = (hashes[:, None, :] != sampled[None, :, :]).sum(-1).astype(jnp.float32)
    score = jnp.exp(jnp.log(diff + EPS).mean(axis=1))  # geomean [S]

    # --- erase / share masks (lines 5-17) ------------------------------
    erase = score >= htop
    share_all = score <= hbot
    any_share = share_all.any()
    base_idx = jnp.argmax(share_all)
    idx = jax.lax.iota(jnp.int32, s_len)
    share_rest = share_all & (idx != base_idx) & any_share
    keep = (~(erase | share_rest)).astype(jnp.float32)

    # --- masked shared-QK attention (line 18) ---------------------------
    qm = qk * keep[:, None]
    scores = jnp.dot(qm, qm.T, preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(d))
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.dot(w, v, preferred_element_type=jnp.float32)

    # --- copy base output into shared rows (line 19) --------------------
    base_row = jnp.take(out, base_idx, axis=0)
    out = jnp.where(share_rest[:, None], base_row[None, :], out)
    o_ref[0] = out


def _hlsh_pallas(qk: jnp.ndarray, v: jnp.ndarray, hashes: jnp.ndarray,
                 htop: float, hbot: float) -> jnp.ndarray:
    """Raw pallas_call: grid = batch; each program owns one (S, D)
    block in VMEM."""
    b, s, d = qk.shape
    h = hashes.shape[-1]
    kernel = functools.partial(_hlsh_kernel, htop=float(htop), hbot=float(hbot))
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, h), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(qk, v, hashes)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def hlsh_attention(qk: jnp.ndarray, v: jnp.ndarray, hashes: jnp.ndarray,
                   htop: float, hbot: float) -> jnp.ndarray:
    """HLSH attention over a batch.

    qk, v: f32 [B, S, D]; hashes: int32 [B, S, H].

    Forward runs the Pallas kernel; the backward pass differentiates
    the pure-jnp reference (pallas_call in interpret mode has no
    reverse-mode rule — and the two are verified numerically identical
    by `tests/test_kernels.py`, so the gradients are exact).
    """
    return _hlsh_pallas(qk, v, hashes, htop, hbot)


def _hlsh_fwd(qk, v, hashes, htop, hbot):
    return _hlsh_pallas(qk, v, hashes, htop, hbot), (qk, v, hashes)


def _hlsh_bwd(htop, hbot, res, g):
    from .ref import hlsh_attention_batched_ref

    qk, v, hashes = res
    _, vjp = jax.vjp(
        lambda q_, v_: hlsh_attention_batched_ref(q_, v_, hashes, htop, hbot), qk, v
    )
    dqk, dv = vjp(g)
    import numpy as np

    dhash = np.zeros(hashes.shape, dtype=jax.dtypes.float0)
    return dqk, dv, dhash


hlsh_attention.defvjp(_hlsh_fwd, _hlsh_bwd)
