"""Pure-jnp reference (oracle) implementations for the Pallas kernels.

These are the ground truth the kernel tests compare against
(`python/tests/test_kernels.py`, hypothesis sweeps), and the fallback
path the models can run when Pallas is unavailable.

HLSH attention = the paper's Algorithm 1:
  1. LSH-bucket the shared Q/K matrix (angular LSH → sign bits).
  2. Sample seq_len/2 key rows; per query row, geomean of Hamming
     distances to the sampled rows → HSCORE.
  3. HSCORE ≥ HTOP  → erase the row (distinct entry, negligible dot
     products).
     HSCORE ≤ HBOT  → share: keep the first such row ("base"), erase
     the rest, and copy base's attention output to them.
  4. Ordinary scaled-dot-product attention over the surviving rows.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-6


def lsh_hash(qk: jnp.ndarray, n_hashes: int, seed: int = 0) -> jnp.ndarray:
    """Angular LSH: sign bits of random projections.

    qk: [..., S, D] → int32 bits [..., S, n_hashes].
    The projection matrix is a fixed function of `seed` (NOT trained),
    shared between train/AOT/runtime so hash codes are reproducible.
    """
    d = qk.shape[-1]
    r = jax.random.normal(jax.random.PRNGKey(seed), (d, n_hashes), dtype=jnp.float32)
    return (qk @ r > 0).astype(jnp.int32)


def hscore(hashes: jnp.ndarray) -> jnp.ndarray:
    """Per-row Hamming score (Algorithm 1 lines 2-3) for [S, H] codes.

    Samples every other row (seq/2 deterministic 'random' sample — the
    simulator must be reproducible), computes the Hamming distance from
    each row to each sample, and reduces by geometric mean.
    """
    sampled = hashes[::2]  # [S/2, H]
    # [S, S/2]: number of differing bits.
    diff = (hashes[:, None, :] != sampled[None, :, :]).sum(-1).astype(jnp.float32)
    # Geometric mean along the sample axis (ε keeps zeros finite).
    return jnp.exp(jnp.log(diff + EPS).mean(axis=1))


def hlsh_masks(hashes: jnp.ndarray, htop: float, hbot: float):
    """Erase/share masks for one sequence [S, H] (Algorithm 1 lines 5-17).

    Returns (keep [S] f32, base_idx scalar int, share [S] bool):
    * keep = 0 for erased rows (score ≥ htop, or shared non-base rows)
    * base_idx = first shared row (or -1 encoded as 0 with empty share)
    * share = rows whose output is copied from base after attention
    """
    s = hscore(hashes)
    erase = s >= htop
    share_all = s <= hbot
    any_share = share_all.any()
    base_idx = jnp.argmax(share_all)  # first True (0 if none — guarded by any_share)
    idx = jnp.arange(hashes.shape[0])
    share_rest = share_all & (idx != base_idx)
    keep = (~(erase | share_rest)).astype(jnp.float32)
    share_rest = share_rest & any_share
    return keep, base_idx, share_rest


def hlsh_attention_ref(qk: jnp.ndarray, v: jnp.ndarray, hashes: jnp.ndarray,
                       htop: float, hbot: float) -> jnp.ndarray:
    """Reference HLSH attention for one sequence.

    qk, v: [S, D]; hashes: [S, H] → out [S, D].
    """
    s_len, d = qk.shape
    keep, base_idx, share_rest = hlsh_masks(hashes, htop, hbot)
    qm = qk * keep[:, None]
    km = qk * keep[:, None]
    scores = qm @ km.T / jnp.sqrt(jnp.float32(d))  # [S, S]
    w = jax.nn.softmax(scores, axis=-1)
    out = w @ v
    # Copy the base row's output into the shared rows (line 19).
    base_row = out[base_idx]
    out = jnp.where(share_rest[:, None], base_row[None, :], out)
    return out


def hlsh_attention_batched_ref(qk, v, hashes, htop: float, hbot: float):
    """vmap over batch: qk, v [B, S, D]; hashes [B, S, H]."""
    return jax.vmap(lambda q_, v_, h_: hlsh_attention_ref(q_, v_, h_, htop, hbot))(qk, v, hashes)


def full_attention_ref(qk: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Single-head shared-QK full attention [B, S, D] (the module HLSH
    approximates; Table 5's comparison baseline)."""
    d = qk.shape[-1]
    scores = qk @ qk.transpose(0, 2, 1) / jnp.sqrt(jnp.float32(d))
    w = jax.nn.softmax(scores, axis=-1)
    return w @ v
