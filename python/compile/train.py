"""Training loop + evaluation metrics (top-k accuracy, weighted F1 —
the paper's Table 1/8 columns), with the paper's quantization-aware
[-8, 8] clamp.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import nn


@dataclass
class TrainResult:
    params: dict
    losses: list
    f1: float
    top1: float
    top10: float


def make_loss_fn(apply_fn):
    def loss_fn(params, tokens, labels):
        return nn.cross_entropy(apply_fn(params, tokens), labels)
    return loss_fn


def make_train_step(apply_fn, lr=1e-3, clamp=False):
    loss_fn = make_loss_fn(apply_fn)

    @jax.jit
    def step(params, opt_state, tokens, labels):
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens, labels)
        params, opt_state = nn.adam_step(params, opt_state, grads, lr=lr)
        if clamp:
            params = nn.clip_params(params)
        return params, opt_state, loss

    return step


def train(init_fn, apply_fn, X, y, *, epochs=3, batch_size=256, lr=1e-3,
          clamp=False, seed=0, eval_data=None, log=None):
    """Train a model; returns TrainResult with validation metrics."""
    key = jax.random.PRNGKey(seed)
    params = init_fn(key)
    opt_state = nn.adam_init(params)
    step = make_train_step(apply_fn, lr=lr, clamp=clamp)

    n = len(X)
    rng = np.random.default_rng(seed)
    losses = []
    for epoch in range(epochs):
        perm = rng.permutation(n)
        epoch_loss, batches = 0.0, 0
        for start in range(0, n - batch_size + 1, batch_size):
            idx = perm[start:start + batch_size]
            params, opt_state, loss = step(params, opt_state, jnp.asarray(X[idx]), jnp.asarray(y[idx]))
            epoch_loss += float(loss)
            batches += 1
        mean_loss = epoch_loss / max(batches, 1)
        losses.append(mean_loss)
        if log:
            log(f"  epoch {epoch}: loss {mean_loss:.4f}")

    Xe, ye = eval_data if eval_data is not None else (X, y)
    metrics = evaluate(apply_fn, params, Xe, ye)
    return TrainResult(params=params, losses=losses, **metrics)


def predict_logits(apply_fn, params, X, batch_size=512):
    """Batched inference over a numpy dataset."""
    jit_apply = jax.jit(apply_fn)
    outs = []
    for start in range(0, len(X), batch_size):
        outs.append(np.asarray(jit_apply(params, jnp.asarray(X[start:start + batch_size]))))
    return np.concatenate(outs)


def evaluate(apply_fn, params, X, y, batch_size=512) -> dict:
    """top-1 / top-10 accuracy + weighted F1 (paper Tables 1-8)."""
    logits = predict_logits(apply_fn, params, X, batch_size)
    return metrics_from_logits(logits, y)


def metrics_from_logits(logits: np.ndarray, y: np.ndarray) -> dict:
    pred = logits.argmax(-1)
    top1 = float((pred == y).mean())
    k = min(10, logits.shape[-1])
    topk = np.argpartition(-logits, kth=k - 1, axis=-1)[:, :k]
    top10 = float((topk == y[:, None]).any(-1).mean())
    return {"f1": weighted_f1(y, pred), "top1": top1, "top10": top10}


def weighted_f1(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    """Support-weighted F1 over the observed classes (sklearn
    `f1_score(average="weighted")` semantics, implemented locally)."""
    classes, support = np.unique(y_true, return_counts=True)
    total = support.sum()
    f1_sum = 0.0
    for c, sup in zip(classes, support):
        tp = float(((y_pred == c) & (y_true == c)).sum())
        fp = float(((y_pred == c) & (y_true != c)).sum())
        fn = float(((y_pred != c) & (y_true == c)).sum())
        prec = tp / (tp + fp) if tp + fp > 0 else 0.0
        rec = tp / (tp + fn) if tp + fn > 0 else 0.0
        f1 = 2 * prec * rec / (prec + rec) if prec + rec > 0 else 0.0
        f1_sum += f1 * sup
    return f1_sum / total if total else 0.0
