"""Layer 2 — the predictor model zoo in pure JAX.

* ``transformer_full`` — the unconstrained model of §4/Table 1:
  encoder-only (BERT-like), 13 embedded features concatenated to
  d_model = 200, sinusoidal positions, 2 encoder layers, full
  multi-head self-attention, linear + softmax head.
* ``revised`` — the §6 predictor: 3 features (PC, page, Δ) embedded to
  d_model = 12, 1 encoder layer, 1 head, **HLSH attention** (the
  Layer-1 Pallas kernel), activations clamped to [-8, 8].
* ``fc`` / ``mlp`` / ``lstm`` / ``cnn`` — Table 4 and Figure 9
  comparison baselines.

Every factory returns ``(init_fn, apply_fn)`` with
``apply_fn(params, tokens int32 [B, S, F]) -> logits f32 [B, C]``.
Parameter dicts flatten in sorted-key order — the AOT argument
convention the Rust runtime relies on.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import nn
from .kernels.hlsh import hlsh_attention
from .kernels.ref import hlsh_attention_batched_ref, lsh_hash

HLSH_N_HASHES = 16
HTOP = 0.9 * HLSH_N_HASHES
HBOT = 0.1 * HLSH_N_HASHES


def _embed_tokens(params, tokens, n_features, prefix="emb"):
    """Concatenate per-feature embeddings: [B,S,F] → [B,S,sum(dims)]."""
    parts = [
        params[f"{prefix}{i}"][tokens[:, :, i]] for i in range(n_features)
    ]
    return jnp.concatenate(parts, axis=-1)


def _embed_init(key, vocab_sizes, dims, prefix="emb"):
    ks = jax.random.split(key, len(vocab_sizes))
    return {
        f"{prefix}{i}": nn.embed_init(ks[i], v, d)
        for i, (v, d) in enumerate(zip(vocab_sizes, dims))
    }


def _head_apply(params, x, clamp=False):
    """Pool the last token and project to classes."""
    h = x[:, -1, :]
    if clamp:
        h = nn.clamp(h)
    return nn.dense(params, "head", h)


# ---------------------------------------------------------------------------
# transformer_full — §4 unconstrained model
# ---------------------------------------------------------------------------

def make_transformer_full(vocab_sizes, n_classes, seq_len=30, n_layers=2,
                          n_heads=4, d_ff=256):
    """13-feature encoder-only Transformer (paper Figure 4).

    Embedding dims are spread over the features so they sum to ~200
    (the paper: "200 is the total dimensions of the concatenation of 13
    features after embedding").
    """
    n_feat = len(vocab_sizes)
    base = 200 // n_feat
    dims = [base + (1 if i < 200 - base * n_feat else 0) for i in range(n_feat)]
    d_model = sum(dims)
    assert d_model % n_heads == 0 or n_heads == 1, (d_model, n_heads)

    def init(key):
        ks = jax.random.split(key, n_layers + 2)
        params = _embed_init(ks[0], vocab_sizes, dims)
        for layer in range(n_layers):
            params.update(nn.encoder_layer_init(ks[1 + layer], d_model, d_ff, f"enc{layer}"))
        params.update(nn.dense_init(ks[-1], d_model, n_classes, "head"))
        return params

    pe = nn.positional_encoding(seq_len, d_model)

    def apply(params, tokens):
        x = _embed_tokens(params, tokens, n_feat) + pe[None, : tokens.shape[1]]
        for layer in range(n_layers):
            x = nn.encoder_layer(params, f"enc{layer}", x, n_heads)
        return _head_apply(params, x)

    return init, apply


# ---------------------------------------------------------------------------
# revised — §6 simplified model (the AOT'd production path)
# ---------------------------------------------------------------------------

def make_revised(vocab_sizes, n_classes, seq_len=30, use_pallas=True,
                 attention="hlsh", quant_clamp=True):
    """The revised predictor (paper §6, Figure 8).

    3 features → 12-dim embedding (4+4+4), positional encoding, one
    single-head encoder block whose attention is the HLSH kernel
    (Layer 1), residual + head. ``attention`` ∈ {"hlsh", "full",
    "none"} — "none" is the FC-only ablation of Table 4, "full" the
    Table 5 baseline.
    """
    n_feat = len(vocab_sizes)
    dims = [4] * n_feat
    d_model = sum(dims)

    def init(key):
        ks = jax.random.split(key, 5)
        params = _embed_init(ks[0], vocab_sizes, dims)
        params.update(nn.dense_init(ks[1], d_model, d_model, "qk"))
        params.update(nn.dense_init(ks[2], d_model, d_model, "v"))
        params.update(nn.dense_init(ks[3], d_model, d_model, "ff"))
        params.update(nn.layer_norm_init(d_model, "ln"))
        params.update(nn.dense_init(ks[4], d_model, n_classes, "head"))
        return params

    pe = nn.positional_encoding(seq_len, d_model)
    attn_fn = hlsh_attention if use_pallas else hlsh_attention_batched_ref

    def apply(params, tokens):
        x = _embed_tokens(params, tokens, n_feat) + pe[None, : tokens.shape[1]]
        if quant_clamp:
            x = nn.clamp(x)
        if attention != "none":
            # Shared-QK projection (Reformer-style — §5.4).
            qk = nn.dense(params, "qk", x)
            v = nn.dense(params, "v", x)
            if quant_clamp:
                qk, v = nn.clamp(qk), nn.clamp(v)
            if attention == "hlsh":
                hashes = lsh_hash(qk, HLSH_N_HASHES)
                a = attn_fn(qk, v, hashes, HTOP, HBOT)
            else:  # "full"
                from .kernels.ref import full_attention_ref
                a = full_attention_ref(qk, v)
            x = nn.layer_norm(params, "ln", x + a)
        h = jax.nn.relu(nn.dense(params, "ff", x))
        if quant_clamp:
            h = nn.clamp(h)
        return _head_apply(params, x + h, clamp=quant_clamp)

    return init, apply


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------

def make_fc(vocab_sizes, n_classes, seq_len=30):
    """Single fully-connected layer over the flattened embeddings
    (paper Table 4's degenerate-case winner)."""
    n_feat = len(vocab_sizes)
    dims = [4] * n_feat
    d_model = sum(dims)

    def init(key):
        ks = jax.random.split(key, 2)
        params = _embed_init(ks[0], vocab_sizes, dims)
        params.update(nn.dense_init(ks[1], seq_len * d_model, n_classes, "head"))
        return params

    def apply(params, tokens):
        x = _embed_tokens(params, tokens, n_feat)
        flat = x.reshape(x.shape[0], -1)
        return nn.dense(params, "head", flat)

    return init, apply


def make_mlp(vocab_sizes, n_classes, seq_len=30, hidden=128):
    """Two-hidden-layer MLP (Fig. 9 baseline; Peled et al. style)."""
    n_feat = len(vocab_sizes)
    dims = [4] * n_feat
    d_model = sum(dims)

    def init(key):
        ks = jax.random.split(key, 4)
        params = _embed_init(ks[0], vocab_sizes, dims)
        params.update(nn.dense_init(ks[1], seq_len * d_model, hidden, "h1"))
        params.update(nn.dense_init(ks[2], hidden, hidden, "h2"))
        params.update(nn.dense_init(ks[3], hidden, n_classes, "head"))
        return params

    def apply(params, tokens):
        x = _embed_tokens(params, tokens, n_feat).reshape(tokens.shape[0], -1)
        x = jax.nn.relu(nn.dense(params, "h1", x))
        x = jax.nn.relu(nn.dense(params, "h2", x))
        return nn.dense(params, "head", x)

    return init, apply


def make_lstm(vocab_sizes, n_classes, seq_len=30, hidden=64):
    """LSTM over the token embeddings (Fig. 9; Hashemi et al. style)."""
    n_feat = len(vocab_sizes)
    dims = [4] * n_feat
    d_model = sum(dims)

    def init(key):
        ks = jax.random.split(key, 3)
        params = _embed_init(ks[0], vocab_sizes, dims)
        params.update(nn.lstm_init(ks[1], d_model, hidden, "lstm"))
        params.update(nn.dense_init(ks[2], hidden, n_classes, "head"))
        return params

    def apply(params, tokens):
        x = _embed_tokens(params, tokens, n_feat)
        h = nn.lstm(params, "lstm", x)
        return nn.dense(params, "head", h)

    return init, apply


def make_cnn(vocab_sizes, n_classes, seq_len=30, channels=64, width=3):
    """1-D CNN over the sequence (Fig. 9 baseline)."""
    n_feat = len(vocab_sizes)
    dims = [4] * n_feat
    d_model = sum(dims)

    def init(key):
        ks = jax.random.split(key, 4)
        params = _embed_init(ks[0], vocab_sizes, dims)
        params.update(nn.conv1d_init(ks[1], d_model, channels, width, "c1"))
        params.update(nn.conv1d_init(ks[2], channels, channels, width, "c2"))
        params.update(nn.dense_init(ks[3], channels, n_classes, "head"))
        return params

    def apply(params, tokens):
        x = _embed_tokens(params, tokens, n_feat)
        x = jax.nn.relu(nn.conv1d(params, "c1", x))
        x = jax.nn.relu(nn.conv1d(params, "c2", x))
        return nn.dense(params, "head", x.mean(axis=1))

    return init, apply


MODEL_FACTORIES = {
    "transformer": make_transformer_full,
    "revised": make_revised,
    "fc": make_fc,
    "mlp": make_mlp,
    "lstm": make_lstm,
    "cnn": make_cnn,
    "hlsh": make_revised,  # Fig. 9 alias
}


def make_model(arch: str, vocab_sizes, n_classes, seq_len=30, **kw):
    factory = MODEL_FACTORIES[arch]
    return factory(vocab_sizes, n_classes, seq_len=seq_len, **kw)


@functools.lru_cache(maxsize=None)
def _noop():  # pragma: no cover - keeps functools import purposeful
    return None
