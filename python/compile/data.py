"""Data pipeline: GMMU trace CSV → clustered, featurized training
sequences (paper §4, Figure 3).

The Rust simulator (`repro trace-gen`) is the single source of truth
for traces; this module never synthesizes access patterns (no parity
drift — DESIGN.md §6).

Feature catalogue (Figure 3, 13 features):
    pc, miss, warp, sm, tpc, cta, page (pAddr), bb (bbAddr),
    root (rAddr), array (In), dpage (Δp), dbb (Δbb), droot (Δr)
The revised predictor (§6) uses ``REVISED_FEATURES`` = (pc, page,
dpage); the unconstrained Transformer uses all 13.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field

import numpy as np

PAGES_PER_BB = 16
PAGES_PER_ROOT = 512

ALL_FEATURES = (
    "pc", "miss", "warp", "sm", "tpc", "cta",
    "page", "bb", "root", "array", "dpage", "dbb", "droot",
)
REVISED_FEATURES = ("pc", "page", "dpage")

CLUSTER_KEYS = ("pc", "kernel_id", "sm", "cta", "warp", "sm_warp")

TRACE_COLUMNS = ("cycle", "pc", "page", "sm", "warp", "cta", "tpc", "kernel_id", "array_id", "miss")


def load_trace(path: str, limit: int = 0) -> dict:
    """Load a trace CSV into column arrays (int64)."""
    data = np.loadtxt(path, delimiter=",", skiprows=1, dtype=np.int64, ndmin=2)
    if limit and len(data) > limit:
        data = data[:limit]
    cols = {name: data[:, i] for i, name in enumerate(TRACE_COLUMNS)}
    return cols


def cluster_ids(trace: dict, cluster_by: str) -> np.ndarray:
    """Cluster key per record (paper §5.1 / Table 2 variants)."""
    if cluster_by == "pc":
        return trace["pc"]
    if cluster_by == "kernel_id":
        return trace["kernel_id"]
    if cluster_by == "sm":
        return trace["sm"]
    if cluster_by == "cta":
        return trace["cta"]
    if cluster_by == "warp":
        return trace["warp"]
    if cluster_by == "sm_warp":
        return (trace["sm"] << 32) | trace["warp"]
    raise ValueError(f"unknown cluster key '{cluster_by}' (one of {CLUSTER_KEYS})")


@dataclass
class Vocab:
    """Feature encoders shared between training and the Rust runtime.

    Output classes = unique page deltas (+ OOV as the last class).
    """

    deltas: list  # class id → delta
    pcs: list  # pc id table
    page_buckets: int = 4096
    dominant_delta: int = 0
    convergence: float = 0.0
    history_len: int = 30
    # Small-cardinality side tables for the 13-feature model.
    aux_sizes: dict = field(default_factory=dict)

    def __post_init__(self):
        self._delta_ids = {d: i for i, d in enumerate(self.deltas)}
        self._pc_ids = {p: i for i, p in enumerate(self.pcs)}

    @property
    def n_classes(self) -> int:
        return len(self.deltas) + 1  # + OOV

    @property
    def oov(self) -> int:
        return len(self.deltas)

    def encode_delta(self, d: int) -> int:
        return self._delta_ids.get(int(d), self.oov)

    def encode_deltas(self, ds: np.ndarray) -> np.ndarray:
        return np.array([self.encode_delta(d) for d in ds], dtype=np.int32)

    def encode_pc(self, pc: int) -> int:
        return self._pc_ids.get(int(pc), len(self.pcs))

    def encode_page(self, page: int) -> int:
        return int(page) % self.page_buckets

    def to_json(self) -> dict:
        return {
            "deltas": [int(d) for d in self.deltas],
            "pcs": [int(p) for p in self.pcs],
            "page_buckets": int(self.page_buckets),
            "dominant_delta": int(self.dominant_delta),
            "convergence": float(self.convergence),
            "history_len": int(self.history_len),
        }

    def save(self, path: str):
        with open(path, "w") as f:
            json.dump(self.to_json(), f)

    @staticmethod
    def from_json(d: dict) -> "Vocab":
        return Vocab(
            deltas=list(d["deltas"]),
            pcs=list(d["pcs"]),
            page_buckets=int(d["page_buckets"]),
            dominant_delta=int(d["dominant_delta"]),
            convergence=float(d["convergence"]),
            history_len=int(d["history_len"]),
        )


def build_vocab(traces: list, history_len: int = 30, max_classes: int = 512,
                page_buckets: int = 4096, cluster_by: str = "sm_warp") -> Vocab:
    """Vocabulary over per-cluster page deltas across one or more traces.

    `max_classes` keeps the output head bounded (the paper notes the
    category count "varies among different benchmarks"); rare deltas
    fall into OOV.
    """
    from collections import Counter

    delta_counts: Counter = Counter()
    pcs: set = set()
    for trace in traces:
        pcs.update(int(p) for p in np.unique(trace["pc"]))
        keys = cluster_ids(trace, cluster_by)
        order = np.argsort(keys, kind="stable")
        sk, sp = keys[order], trace["page"][order]
        same = sk[1:] == sk[:-1]
        deltas = (sp[1:] - sp[:-1])[same]
        delta_counts.update(int(d) for d in deltas)

    total = sum(delta_counts.values()) or 1
    most = delta_counts.most_common(max_classes)
    deltas = [d for d, _ in most]
    dominant, dom_count = most[0] if most else (0, 0)
    return Vocab(
        deltas=deltas,
        pcs=sorted(pcs),
        page_buckets=page_buckets,
        dominant_delta=dominant,
        convergence=dom_count / total,
        history_len=history_len,
    )


def _per_cluster_sequences(trace: dict, cluster_by: str):
    """Yield (key, index array) per cluster, preserving record order."""
    keys = cluster_ids(trace, cluster_by)
    order = np.argsort(keys, kind="stable")
    sk = keys[order]
    boundaries = np.nonzero(sk[1:] != sk[:-1])[0] + 1
    for chunk in np.split(order, boundaries):
        if len(chunk) > 1:
            yield int(keys[chunk[0]]), chunk


def featurize_cluster(trace: dict, idx: np.ndarray, vocab: Vocab,
                      features=REVISED_FEATURES) -> np.ndarray:
    """Encode one cluster's records to an int32 feature matrix [T, F].

    The first record has no delta and is dropped (matching the Rust
    `ClusterHistory` semantics).
    """
    pages = trace["page"][idx]
    deltas = pages[1:] - pages[:-1]
    idx = idx[1:]
    pages = pages[1:]
    out = np.zeros((len(idx), len(features)), dtype=np.int32)
    for f_i, name in enumerate(features):
        if name == "pc":
            out[:, f_i] = [vocab.encode_pc(p) for p in trace["pc"][idx]]
        elif name == "page":
            out[:, f_i] = pages % vocab.page_buckets
        elif name == "dpage":
            out[:, f_i] = vocab.encode_deltas(deltas)
        elif name == "bb":
            out[:, f_i] = (pages // PAGES_PER_BB) % vocab.page_buckets
        elif name == "root":
            out[:, f_i] = (pages // PAGES_PER_ROOT) % vocab.page_buckets
        elif name == "dbb":
            dbb = (pages // PAGES_PER_BB) - (np.concatenate([[pages[0] // PAGES_PER_BB], pages[:-1] // PAGES_PER_BB]))
            out[:, f_i] = np.clip(dbb + 64, 0, 127)
        elif name == "droot":
            droot = (pages // PAGES_PER_ROOT) - (np.concatenate([[pages[0] // PAGES_PER_ROOT], pages[:-1] // PAGES_PER_ROOT]))
            out[:, f_i] = np.clip(droot + 8, 0, 15)
        elif name == "miss":
            out[:, f_i] = trace["miss"][idx]
        elif name == "warp":
            out[:, f_i] = trace["warp"][idx] % 64
        elif name == "sm":
            out[:, f_i] = trace["sm"][idx] % 64
        elif name == "tpc":
            out[:, f_i] = trace["tpc"][idx] % 32
        elif name == "cta":
            out[:, f_i] = trace["cta"][idx] % 256
        elif name == "array":
            out[:, f_i] = trace["array_id"][idx] % 16
        else:
            raise ValueError(f"unknown feature '{name}'")
    labels = vocab.encode_deltas(deltas)  # delta id of THIS record
    return out, labels


def build_dataset(trace: dict, vocab: Vocab, cluster_by: str = "sm_warp",
                  features=REVISED_FEATURES, seq_len: int = 30,
                  distance: int = 1, max_samples: int = 200_000,
                  shuffle_seed: int = 0):
    """Sliding-window sequence dataset.

    X[i] = tokens t-seq_len+1 … t;  y[i] = delta class at t + distance
    (paper §5.2: the prediction distance; Table 3 sweeps 1 vs 30).

    Returns (X [N, seq_len, F] int32, y [N] int32).
    """
    xs, ys = [], []
    budget = max_samples
    for _key, idx in _per_cluster_sequences(trace, cluster_by):
        feats, labels = featurize_cluster(trace, idx, vocab, features)
        t_count = len(feats) - seq_len - distance + 1
        if t_count <= 0:
            continue
        windows = np.lib.stride_tricks.sliding_window_view(
            feats, (seq_len, feats.shape[1])
        )[:, 0][:t_count]
        lbl = labels[seq_len + distance - 1:seq_len + distance - 1 + t_count]
        xs.append(windows.astype(np.int32))
        ys.append(lbl.astype(np.int32))
        budget -= t_count
        if budget <= 0:
            break
    if not xs:
        raise ValueError("trace too small for the requested seq_len/distance")
    X = np.concatenate(xs)
    y = np.concatenate(ys)
    rng = np.random.default_rng(shuffle_seed)
    perm = rng.permutation(len(X))
    X, y = X[perm], y[perm]
    if len(X) > max_samples:
        X, y = X[:max_samples], y[:max_samples]
    return X, y


def split_dataset(X, y, train_frac: float = 0.8):
    """The paper's §4 split: 80 % train / 20 % validation."""
    n = int(len(X) * train_frac)
    return (X[:n], y[:n]), (X[n:], y[n:])


def feature_vocab_sizes(vocab: Vocab, features=REVISED_FEATURES) -> list:
    """Embedding-table size per feature (order matches the tokens)."""
    sizes = []
    for name in features:
        if name == "pc":
            sizes.append(len(vocab.pcs) + 1)  # + PC-OOV
        elif name in ("page", "bb", "root"):
            sizes.append(vocab.page_buckets)
        elif name == "dpage":
            sizes.append(vocab.n_classes)
        elif name == "dbb":
            sizes.append(128)
        elif name == "droot":
            sizes.append(16)
        elif name == "miss":
            sizes.append(2)
        elif name in ("warp", "sm"):
            sizes.append(64)
        elif name == "tpc":
            sizes.append(32)
        elif name == "cta":
            sizes.append(256)
        elif name == "array":
            sizes.append(16)
        else:
            raise ValueError(name)
    return sizes


def trace_path(traces_dir: str, benchmark: str) -> str:
    return os.path.join(traces_dir, f"{benchmark}.csv")
