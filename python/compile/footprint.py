"""Model memory-footprint accounting (paper Tables 6/7).

The paper measures parameters + forward/backward activation memory with
torchinfo. We compute the same quantities analytically from the jaxpr:

* parameter bytes — sum of leaf sizes × 4 (f32);
* activation bytes — the sum of every intermediate array produced while
  evaluating loss + gradients (a faithful stand-in for torchinfo's
  "forward/backward pass size", which likewise counts stored
  activations for both passes);
* the Table 7 "revised" row additionally reports the 4-bit storage
  estimate (paper §6: "4 bits are enough to represent all the integers
  within [-8, +8]" ⇒ ⅛ of f32).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import nn


def param_bytes(params) -> int:
    return sum(int(np.prod(l.shape)) * 4 for l in jax.tree_util.tree_leaves(params))


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def activation_bytes(apply_fn, params, batch: int, seq_len: int, n_feat: int) -> int:
    """Sum of intermediate arrays in the fwd+bwd jaxpr."""
    tokens = jnp.zeros((batch, seq_len, n_feat), jnp.int32)
    labels = jnp.zeros((batch,), jnp.int32)

    def loss(p):
        return nn.cross_entropy(apply_fn(p, tokens), labels)

    jaxpr = jax.make_jaxpr(jax.value_and_grad(loss))(params)

    total = 0

    def walk(jpr):
        nonlocal total
        for eqn in jpr.eqns:
            for v in eqn.outvars:
                total += _aval_bytes(v.aval)
            # Recurse into nested jaxprs (custom_vjp, scan, …).
            for p in eqn.params.values():
                if hasattr(p, "jaxpr"):
                    inner = p.jaxpr if hasattr(p.jaxpr, "eqns") else p
                    walk(inner if hasattr(inner, "eqns") else inner.jaxpr)
                elif isinstance(p, (list, tuple)):
                    for q in p:
                        if hasattr(q, "jaxpr"):
                            walk(q.jaxpr if hasattr(q.jaxpr, "eqns") else q)

    walk(jaxpr.jaxpr)
    return total


def footprint(apply_fn, params, batch: int = 512, seq_len: int = 30,
              n_feat: int = 3) -> dict:
    """Tables 6/7 row: params / activations / total, in bytes."""
    pb = param_bytes(params)
    ab = activation_bytes(apply_fn, params, batch, seq_len, n_feat)
    return {
        "params_bytes": pb,
        "activation_bytes": ab,
        "total_bytes": pb + ab,
        "params_int4_bytes": (pb // 4 + 1) // 2,  # f32 → 4-bit codes
    }


def fmt_mb(b: int) -> str:
    if b < 1 << 20:
        return f"{b / 1024:.2f}KB"
    return f"{b / (1 << 20):.2f}MB"
