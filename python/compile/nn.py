"""Minimal pure-JAX neural-network library (no flax/optax in the build
environment — and the models here are tiny, so explicit param dicts keep
the AOT parameter ordering trivially stable for the Rust runtime).

Every layer is an (init, apply) pair over plain dicts. Parameter trees
flatten in sorted-key order (jax dict flattening), which `aot.py` relies
on for the executable argument order.

Quantization-aware mode: the paper clamps weights and activations to
[-8, +8] (§6, Table 8 "R"). `clamp()` is applied to activations inside
the revised model, and `clip_params` is applied to weights after each
optimizer step.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

QUANT_LO, QUANT_HI = -8.0, 8.0


def clamp(x):
    """The paper's [-8, 8] activation clamp."""
    return jnp.clip(x, QUANT_LO, QUANT_HI)


def clip_params(params):
    """Clamp every weight tensor to [-8, 8] (post-step projection)."""
    return jax.tree_util.tree_map(lambda p: jnp.clip(p, QUANT_LO, QUANT_HI), params)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def glorot(key, shape):
    fan_in, fan_out = shape[-2], shape[-1]
    scale = math.sqrt(2.0 / (fan_in + fan_out))
    return jax.random.normal(key, shape, dtype=jnp.float32) * scale


def embed_init(key, vocab, dim):
    return jax.random.normal(key, (vocab, dim), dtype=jnp.float32) * 0.02


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------

def dense_init(key, d_in, d_out, prefix):
    kw, _ = jax.random.split(key)
    return {f"{prefix}_w": glorot(kw, (d_in, d_out)), f"{prefix}_b": jnp.zeros((d_out,), jnp.float32)}


def dense(params, prefix, x):
    return x @ params[f"{prefix}_w"] + params[f"{prefix}_b"]


def layer_norm_init(dim, prefix):
    return {f"{prefix}_g": jnp.ones((dim,), jnp.float32), f"{prefix}_b": jnp.zeros((dim,), jnp.float32)}


def layer_norm(params, prefix, x, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * params[f"{prefix}_g"] + params[f"{prefix}_b"]


def positional_encoding(seq_len: int, dim: int) -> jnp.ndarray:
    """The original sinusoidal scheme (Vaswani et al.; paper §4)."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    i = jnp.arange(dim, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, (2 * (i // 2)) / dim)
    pe = jnp.where(i % 2 == 0, jnp.sin(angle), jnp.cos(angle))
    return pe  # [seq, dim]


def full_attention(q, k, v, n_heads: int):
    """Multi-head scaled dot-product self-attention over [B, S, D]."""
    b, s, d = q.shape
    dh = d // n_heads

    def split(x):
        return x.reshape(b, s, n_heads, dh).transpose(0, 2, 1, 3)  # [B,H,S,dh]

    qh, kh, vh = split(q), split(k), split(v)
    scores = qh @ kh.transpose(0, 1, 3, 2) / math.sqrt(dh)  # [B,H,S,S]
    w = jax.nn.softmax(scores, axis=-1)
    out = w @ vh  # [B,H,S,dh]
    return out.transpose(0, 2, 1, 3).reshape(b, s, d)


def encoder_layer_init(key, d_model, d_ff, prefix):
    ks = jax.random.split(key, 5)
    p = {}
    p.update(dense_init(ks[0], d_model, d_model, f"{prefix}_q"))
    p.update(dense_init(ks[1], d_model, d_model, f"{prefix}_k"))
    p.update(dense_init(ks[2], d_model, d_model, f"{prefix}_v"))
    p.update(dense_init(ks[3], d_model, d_ff, f"{prefix}_ff1"))
    p.update(dense_init(ks[4], d_ff, d_model, f"{prefix}_ff2"))
    p.update(layer_norm_init(d_model, f"{prefix}_ln1"))
    p.update(layer_norm_init(d_model, f"{prefix}_ln2"))
    return p


def encoder_layer(params, prefix, x, n_heads):
    """Post-LN transformer encoder layer (BERT-style, paper Figure 4)."""
    q = dense(params, f"{prefix}_q", x)
    k = dense(params, f"{prefix}_k", x)
    v = dense(params, f"{prefix}_v", x)
    a = full_attention(q, k, v, n_heads)
    x = layer_norm(params, f"{prefix}_ln1", x + a)
    h = jax.nn.relu(dense(params, f"{prefix}_ff1", x))
    h = dense(params, f"{prefix}_ff2", h)
    return layer_norm(params, f"{prefix}_ln2", x + h)


# ---------------------------------------------------------------------------
# LSTM (Fig. 9 baseline)
# ---------------------------------------------------------------------------

def lstm_init(key, d_in, d_hidden, prefix):
    ks = jax.random.split(key, 2)
    return {
        f"{prefix}_wx": glorot(ks[0], (d_in, 4 * d_hidden)),
        f"{prefix}_wh": glorot(ks[1], (d_hidden, 4 * d_hidden)),
        f"{prefix}_b": jnp.zeros((4 * d_hidden,), jnp.float32),
    }


def lstm(params, prefix, x):
    """Run an LSTM over [B, S, D]; returns final hidden state [B, H]."""
    b, s, _ = x.shape
    h_dim = params[f"{prefix}_wh"].shape[0]

    def cell(carry, xt):
        h, c = carry
        gates = xt @ params[f"{prefix}_wx"] + h @ params[f"{prefix}_wh"] + params[f"{prefix}_b"]
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    init = (jnp.zeros((b, h_dim), jnp.float32), jnp.zeros((b, h_dim), jnp.float32))
    (h, _), _ = jax.lax.scan(cell, init, x.transpose(1, 0, 2))
    return h


# ---------------------------------------------------------------------------
# Conv1D (Fig. 9 CNN baseline)
# ---------------------------------------------------------------------------

def conv1d_init(key, d_in, d_out, width, prefix):
    return {
        f"{prefix}_w": glorot(key, (width, d_in, d_out)) ,
        f"{prefix}_b": jnp.zeros((d_out,), jnp.float32),
    }


def conv1d(params, prefix, x):
    """'SAME' 1-D convolution over [B, S, D]."""
    w = params[f"{prefix}_w"]  # [W, Din, Dout]
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(1,), padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"),
    )
    return out + params[f"{prefix}_b"]


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.float32)}


def adam_step(params, opt_state, grads, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = opt_state["t"] + 1.0
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, opt_state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, opt_state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t)
    vhat_scale = 1.0 / (1 - b2 ** t)
    params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v,
    )
    return params, {"m": m, "v": v, "t": t}


def sgd_step(params, grads, lr=0.05):
    """Plain SGD — the online fine-tune step baked into the AOT train
    executable (small and stateless, so Rust carries no optimizer
    state)."""
    return jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
