"""Model-quality experiment harness — regenerates the paper's
Tables 1-8 and Figures 5/6/9 from the trace corpus.

    python -m compile.experiments all --traces ../traces --out ../results
    python -m compile.experiments table1 [--epochs 3] [--samples 30000]

Each experiment prints a markdown table with the paper's value quoted
alongside, and writes `<exp>.csv` under --out. Training runs are cached
per configuration within one invocation (the `all` target reuses the
Table 1 transformers for Fig. 6 etc.).

System-level experiments (Tables 10/11, Figs 10/11/12) live on the
Rust side: `repro eval all`.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from . import data as D
from . import footprint as FP
from .model import make_model
from .train import evaluate, metrics_from_logits, predict_logits, train

PAPER = {
    # benchmark → (f1, top1, top10) from Table 1.
    "table1": {
        "addvectors": (0.9785, 0.9767, 0.9931),
        "atax": (0.9904, 0.9943, 0.9981),
        "backprop": (0.9175, 0.8893, 0.9974),
        "bicg": (0.9932, 0.9959, 0.9992),
        "hotspot": (0.7611, 0.7676, 0.9933),
        "mvt": (0.9889, 0.9936, 0.9979),
        "nw": (0.97, 0.964, 0.9958),
        "pathfinder": (0.9128, 0.9119, 0.9996),
        "srad_v2": (0.9708, 0.9707, 0.9994),
    },
}

MODEL_BENCHMARKS = (
    "addvectors", "atax", "backprop", "bicg", "hotspot",
    "mvt", "nw", "pathfinder", "srad_v2",
)


class Harness:
    def __init__(self, traces_dir, out_dir, epochs=3, samples=30000, seq_len=30, seed=0):
        self.traces_dir = traces_dir
        self.out_dir = out_dir
        self.epochs = epochs
        self.samples = samples
        self.seq_len = seq_len
        self.seed = seed
        self._traces = {}
        self._runs = {}
        self.t0 = time.time()
        os.makedirs(out_dir, exist_ok=True)

    def log(self, msg):
        print(f"[exp +{time.time() - self.t0:6.1f}s] {msg}", flush=True)

    def trace(self, benchmark):
        if benchmark not in self._traces:
            self._traces[benchmark] = D.load_trace(
                D.trace_path(self.traces_dir, benchmark), 300_000)
        return self._traces[benchmark]

    def run(self, benchmark, arch="transformer", features=None, cluster_by="sm_warp",
            distance=1, seq_len=None, **model_kw):
        """Train one configuration (cached); returns a result dict with
        metrics, params, apply_fn, vocab and the validation split."""
        seq_len = seq_len or self.seq_len
        features = features or (D.ALL_FEATURES if arch == "transformer" else D.REVISED_FEATURES)
        key = (benchmark, arch, tuple(features), cluster_by, distance, seq_len,
               tuple(sorted(model_kw.items())))
        if key in self._runs:
            return self._runs[key]

        t = self.trace(benchmark)
        vocab = D.build_vocab([t], history_len=seq_len, cluster_by=cluster_by)
        try:
            X, y = D.build_dataset(t, vocab, cluster_by=cluster_by, features=features,
                                   seq_len=seq_len, distance=distance,
                                   max_samples=self.samples)
        except ValueError as e:
            # Degenerate configuration (e.g. distance-30 windows over a
            # clustering that fragments the trace): record zeros rather
            # than aborting the whole table.
            self.log(f"  {benchmark}/{arch}: {e} — recording zeros")
            out = {"benchmark": benchmark, "arch": arch, "f1": 0.0, "top1": 0.0,
                   "top10": 0.0, "params": None, "apply": None, "vocab": vocab,
                   "eval": (None, None), "features": features}
            self._runs[key] = out
            return out
        (Xtr, ytr), (Xva, yva) = D.split_dataset(X, y)
        sizes = D.feature_vocab_sizes(vocab, features)
        init, apply = make_model(arch, sizes, vocab.n_classes, seq_len=seq_len, **model_kw)
        self.log(f"train {benchmark}/{arch} feats={len(features)} cluster={cluster_by} "
                 f"dist={distance} n={len(Xtr)} classes={vocab.n_classes}")
        res = train(init, apply, Xtr, ytr, epochs=self.epochs,
                    clamp=(arch in ("revised", "hlsh")), eval_data=(Xva, yva),
                    seed=self.seed)
        out = {
            "benchmark": benchmark, "arch": arch, "f1": res.f1, "top1": res.top1,
            "top10": res.top10, "params": res.params, "apply": apply,
            "vocab": vocab, "eval": (Xva, yva), "features": features,
        }
        self._runs[key] = out
        return out

    # ------------------------------------------------------------------
    # experiments
    # ------------------------------------------------------------------

    def table(self, name, headers, rows):
        width = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
                 for i, h in enumerate(headers)]
        lines = ["", f"### {name}", ""]
        lines.append("| " + " | ".join(h.ljust(w) for h, w in zip(headers, width)) + " |")
        lines.append("|" + "|".join("-" * (w + 2) for w in width) + "|")
        for r in rows:
            lines.append("| " + " | ".join(c.ljust(w) for c, w in zip(r, width)) + " |")
        text = "\n".join(lines)
        print(text)
        csv_path = os.path.join(self.out_dir, f"{name.split(' ')[0].lower()}.csv")
        with open(csv_path, "w") as f:
            f.write(",".join(headers) + "\n")
            for r in rows:
                f.write(",".join(r) + "\n")
        return text

    def table1(self):
        """Full-Transformer prediction quality (paper Table 1)."""
        rows = []
        for b in MODEL_BENCHMARKS:
            r = self.run(b, "transformer")
            paper = PAPER["table1"].get(b, ("-", "-", "-"))
            rows.append([b, f"{r['f1']:.4f}", f"{r['top1']:.4f}", f"{r['top10']:.4f}",
                         f"{paper[0]}/{paper[1]}"])
        return self.table("Table1 — Transformer-based UVM page prediction",
                          ["benchmark", "f1", "top1", "top10", "paper(f1/top1)"], rows)

    def table2(self):
        """Clustering-method comparison on AddVectors + NW (Table 2)."""
        rows = []
        for b in ("addvectors", "nw"):
            for cl in ("pc", "kernel_id", "sm", "cta", "warp", "sm_warp"):
                r = self.run(b, "transformer", cluster_by=cl)
                rows.append([b, cl, f"{r['f1']:.4f}", f"{r['top1']:.4f}"])
        return self.table("Table2 — prediction by clustering method",
                          ["benchmark", "cluster", "f1", "top1"], rows)

    def table3(self):
        """Prediction distance 1 vs 30 (Table 3)."""
        rows = []
        for b in ("backprop", "srad_v2", "atax", "nw"):
            for dist in (1, 30):
                r = self.run(b, "transformer", distance=dist)
                rows.append([b, str(dist), f"{r['f1']:.4f}", f"{r['top1']:.4f}"])
        return self.table("Table3 — prediction distances",
                          ["benchmark", "distance", "f1", "top1"], rows)

    def fig5(self):
        """Single-feature ablation (Figure 5)."""
        rows = []
        for feat in D.ALL_FEATURES:
            accs = []
            for b in ("addvectors", "nw"):
                r = self.run(b, "transformer", features=(feat,))
                accs.append(r["top1"])
            rows.append([feat, f"{np.mean(accs):.4f}"])
        return self.table("Fig5 — single-feature top-1 accuracy",
                          ["feature", "top1(mean of addvectors,nw)"], rows)

    def fig6(self):
        """Delta convergence vs shuffled-order degradation (Figure 6)."""
        rows = []
        for b in MODEL_BENCHMARKS:
            r = self.run(b, "transformer")
            Xva, yva = r["eval"]
            if Xva is None:
                continue
            rng = np.random.default_rng(0)
            perm = rng.permutation(Xva.shape[1])
            logits = predict_logits(r["apply"], r["params"], Xva[:, perm, :])
            shuffled = metrics_from_logits(logits, yva)
            rows.append([
                b, f"{r['vocab'].convergence:.3f}", f"{r['top1']:.4f}",
                f"{shuffled['top1']:.4f}", f"{r['top1'] - shuffled['top1']:.4f}",
            ])
        return self.table("Fig6 — delta convergence vs shuffle degradation",
                          ["benchmark", "convergence", "top1", "top1_shuffled", "drop"], rows)

    def table4(self):
        """Transformer vs plain FC layer (Table 4)."""
        rows = []
        for b in ("atax", "bicg", "nw", "backprop"):
            for arch in ("transformer", "fc"):
                r = self.run(b, arch)
                rows.append([b, arch, f"{r['f1']:.4f}", f"{r['top1']:.4f}"])
        return self.table("Table4 — Transformer vs FC layer",
                          ["benchmark", "predictor", "f1", "top1"], rows)

    def table5(self):
        """Full attention vs HLSH attention in the revised model (Table 5)."""
        rows = []
        for b in ("atax", "bicg", "nw", "backprop"):
            for attn in ("full", "hlsh"):
                r = self.run(b, "revised", attention=attn)
                rows.append([b, attn, f"{r['f1']:.4f}", f"{r['top1']:.4f}"])
        return self.table("Table5 — full vs HLSH attention",
                          ["benchmark", "attention", "f1", "top1"], rows)

    def table6(self):
        """Footprint of the full Transformer (Table 6)."""
        rows = []
        for b in MODEL_BENCHMARKS:
            r = self.run(b, "transformer")
            if r["params"] is None:
                continue
            fp = FP.footprint(r["apply"], r["params"], batch=512,
                              seq_len=self.seq_len, n_feat=len(r["features"]))
            rows.append([b, FP.fmt_mb(fp["params_bytes"]), FP.fmt_mb(fp["activation_bytes"]),
                         FP.fmt_mb(fp["total_bytes"])])
        return self.table("Table6 — footprint, full Transformer",
                          ["benchmark", "params", "f/b activations", "total"], rows)

    def table7(self):
        """Footprint of the revised predictor incl. int4 storage (Table 7)."""
        rows = []
        for b in MODEL_BENCHMARKS:
            r = self.run(b, "revised")
            if r["params"] is None:
                continue
            fp = FP.footprint(r["apply"], r["params"], batch=512,
                              seq_len=self.seq_len, n_feat=3)
            rows.append([b, FP.fmt_mb(fp["params_bytes"]), FP.fmt_mb(fp["params_int4_bytes"]),
                         FP.fmt_mb(fp["activation_bytes"]), FP.fmt_mb(fp["total_bytes"])])
        return self.table("Table7 — footprint, revised predictor",
                          ["benchmark", "params(f32)", "params(int4)", "f/b activations", "total"],
                          rows)

    def table8(self):
        """Full Transformer vs revised predictor accuracy (Table 8)."""
        rows = []
        for b in MODEL_BENCHMARKS:
            rt = self.run(b, "transformer")
            rr = self.run(b, "revised")
            rows.append([b, f"{rt['f1']:.4f}", f"{rt['top1']:.4f}",
                         f"{rr['f1']:.4f}", f"{rr['top1']:.4f}"])
        return self.table("Table8 — Transformer(T) vs revised(R)",
                          ["benchmark", "f1(T)", "top1(T)", "f1(R)", "top1(R)"], rows)

    def fig9(self):
        """Predictor-zoo comparison (Figure 9)."""
        rows = []
        for arch in ("cnn", "lstm", "mlp", "transformer", "hlsh"):
            accs = []
            for b in MODEL_BENCHMARKS:
                r = self.run(b, arch)
                accs.append(r["top1"])
            rows.append([arch, f"{np.mean(accs):.4f}", f"{min(accs):.4f}", f"{max(accs):.4f}"])
        return self.table("Fig9 — predictor comparison (top-1)",
                          ["predictor", "mean", "min", "max"], rows)

    ALL = ("table1", "table2", "table3", "fig5", "fig6", "table4", "table5",
           "table6", "table7", "table8", "fig9")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("which",
                    help="experiment name, comma-list, or 'all' "
                         f"(choices: {', '.join(Harness.ALL)})")
    ap.add_argument("--traces", default="../traces")
    ap.add_argument("--out", default="../results")
    ap.add_argument("--epochs", type=int, default=int(os.environ.get("EXP_EPOCHS", "3")))
    ap.add_argument("--samples", type=int, default=int(os.environ.get("EXP_SAMPLES", "30000")))
    ap.add_argument("--seq-len", type=int, default=30)
    args = ap.parse_args()

    h = Harness(args.traces, args.out, epochs=args.epochs, samples=args.samples,
                seq_len=args.seq_len)
    targets = Harness.ALL if args.which == "all" else tuple(args.which.split(","))
    report = []
    for t in targets:
        report.append(getattr(h, t)())
    with open(os.path.join(args.out, "model_experiments.md"), "w") as f:
        f.write("\n".join(report))
    h.log("done")


if __name__ == "__main__":
    main()
